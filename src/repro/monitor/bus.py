"""TelemetryBus — cached, incremental, streaming snapshot distribution.

The bus sits between :class:`~repro.monitor.source.MetricSource`s and
every consumer (CLI/watch, archiver, analysis) — DESIGN.md §5:

  * **cached reads** — ``read(name)`` serves the last snapshot while it is
    younger than ``ttl_s``; N readers cost one collection (the paper's
    "don't hammer the scheduler" rule, generalized).
  * **ring buffer** — the last ``history`` snapshots per source, for
    trend queries and late subscribers.
  * **incremental deltas** — per-source normalized-load trend and a
    per-user GPU duty-cycle EWMA, updated once per collection instead of
    recomputed from scratch by each consumer.
  * **background sampler** — ``start()`` polls each source at its
    ``interval_hint`` (or the bus default) on a daemon thread, so watch
    mode and subscribers stream without any consumer driving collection.
  * **subscribers** — callables invoked as ``fn(source_name, snapshot)``
    on every *new* collection (the 15-minute archiver, the daemon's
    HistoryStore, and the insight engine's streaming evaluator —
    DESIGN.md §8 — are all subscribers).

Job-side publishing (``publish_step_utilization``) also lives here: the
trainer/server call this monitor-layer hook, which feeds the in-process
:class:`~repro.core.collector.JaxJobRegistry`; the ``live``/``jobs``
sources read the registry, so published steps reach any bus those
sources are registered on at its next collection.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Deque, Dict, List, Optional

from repro.core.metrics import ClusterSnapshot

Subscriber = Callable[[str, ClusterSnapshot], None]


@dataclasses.dataclass
class SourceStats:
    """Per-source bus counters (reads vs. actual collections)."""
    reads: int = 0
    cache_hits: int = 0
    collections: int = 0
    errors: int = 0


@dataclasses.dataclass
class _Entry:
    source: object
    ring: Deque[ClusterSnapshot]
    stats: SourceStats
    collected_at: Optional[float] = None   # monotonic
    duty_ewma: Dict[str, float] = dataclasses.field(default_factory=dict)
    # serializes collection per source: without it, a reader at TTL expiry
    # and the sampler would both call snapshot(), double-advancing stateful
    # sources (archive replay frames, sim time)
    collect_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock)


class TelemetryBus:
    def __init__(self, *, ttl_s: float = 5.0, history: int = 64,
                 ewma_alpha: float = 0.3):
        self.ttl_s = ttl_s
        self.history = history
        self.ewma_alpha = ewma_alpha
        self._entries: Dict[str, _Entry] = {}        # guarded-by: _lock
        self._subscribers: List[Subscriber] = []     # guarded-by: _lock
        self._lock = threading.RLock()
        # llcheck: ignore[LL001] lifecycle field: start()/stop() are only called from the owning thread
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # --------------------------------------------------------------- wiring
    def register(self, source):
        """Register a source; returns it for chaining."""
        with self._lock:
            if source.name in self._entries:
                raise ValueError(f"source {source.name!r} already registered")
            self._entries[source.name] = _Entry(
                source=source,
                ring=collections.deque(maxlen=self.history),
                stats=SourceStats())
        return source

    def sources(self) -> List[str]:
        with self._lock:
            return sorted(self._entries)

    def subscribe(self, fn: Subscriber) -> Subscriber:
        with self._lock:
            self._subscribers.append(fn)
        return fn

    def unsubscribe(self, fn: Subscriber):
        with self._lock:
            if fn in self._subscribers:
                self._subscribers.remove(fn)

    def _entry(self, name: Optional[str]) -> _Entry:
        with self._lock:
            if name is None:
                if len(self._entries) != 1:
                    raise ValueError(
                        "bus has %d sources; pass name= (one of %s)"
                        % (len(self._entries), self.sources()))
                return next(iter(self._entries.values()))
            return self._entries[name]

    # --------------------------------------------------------------- reads
    def read(self, name: Optional[str] = None, *,
             max_age_s: Optional[float] = None) -> ClusterSnapshot:
        """Cached read: re-collect only when the cached snapshot is older
        than ``max_age_s`` (default: the bus TTL)."""
        ttl = self.ttl_s if max_age_s is None else max_age_s
        entry = self._entry(name)
        with self._lock:
            entry.stats.reads += 1
            if self._fresh(entry, ttl):
                entry.stats.cache_hits += 1
                return entry.ring[-1]
        return self._collect(entry, skip_if_fresh_within=ttl,
                             count_hit=True)

    def poll(self, name: Optional[str] = None) -> ClusterSnapshot:
        """Force a collection now."""
        return self._collect(self._entry(name))

    def history_of(self, name: Optional[str] = None) -> List[ClusterSnapshot]:
        with self._lock:
            return list(self._entry(name).ring)

    def stats(self, name: Optional[str] = None) -> SourceStats:
        with self._lock:
            return dataclasses.replace(self._entry(name).stats)

    # -------------------------------------------------------------- deltas
    def load_trend(self, name: Optional[str] = None) -> float:
        """d(mean normalized load)/dt over the ring buffer (1/s).  Positive
        means the cluster is heating up; 0 with <2 snapshots."""
        with self._lock:
            ring = list(self._entry(name).ring)
        if len(ring) < 2:
            return 0.0
        first, last = ring[0], ring[-1]
        dt = last.timestamp - first.timestamp
        if dt <= 0:
            return 0.0

        def mean_norm(snap: ClusterSnapshot) -> float:
            if not snap.nodes:
                return 0.0
            return sum(n.norm_load for n in snap.nodes.values()) \
                / len(snap.nodes)

        return (mean_norm(last) - mean_norm(first)) / dt

    def gpu_duty_ewma(self, name: Optional[str] = None) -> Dict[str, float]:
        """Per-user EWMA of mean GPU duty cycle across their GPU nodes,
        updated incrementally at each collection."""
        with self._lock:
            return dict(self._entry(name).duty_ewma)

    # ------------------------------------------------------------- collect
    def _fresh(self, entry: _Entry, ttl: float) -> bool:
        return bool(entry.collected_at is not None and entry.ring
                    and time.monotonic() - entry.collected_at < ttl)

    def _collect(self, entry: _Entry,
                 skip_if_fresh_within: Optional[float] = None,
                 count_hit: bool = False) -> ClusterSnapshot:
        with entry.collect_lock:
            if skip_if_fresh_within is not None:
                # another thread may have collected while we waited
                with self._lock:
                    if self._fresh(entry, skip_if_fresh_within):
                        if count_hit:
                            entry.stats.cache_hits += 1
                        return entry.ring[-1]
            try:
                snap = entry.source.snapshot()
            except Exception:
                with self._lock:
                    entry.stats.errors += 1
                raise
            with self._lock:
                entry.ring.append(snap)
                entry.collected_at = time.monotonic()
                entry.stats.collections += 1
                self._update_ewma(entry, snap)
                subscribers = list(self._subscribers)
        for fn in subscribers:   # outside the locks: subscribers may be slow
            fn(entry.source.name, snap)
        return snap

    def _update_ewma(self, entry: _Entry, snap: ClusterSnapshot):
        a = self.ewma_alpha
        for user, hosts in snap.nodes_by_user().items():
            gpu_nodes = [snap.nodes[h] for h in hosts
                         if h in snap.nodes and snap.nodes[h].gpus_total > 0]
            if not gpu_nodes:
                continue
            duty = sum(n.gpu_load for n in gpu_nodes) / len(gpu_nodes)
            prev = entry.duty_ewma.get(user)
            entry.duty_ewma[user] = (duty if prev is None
                                     else a * duty + (1 - a) * prev)

    # ------------------------------------------------------------- sampler
    def start(self, interval_s: Optional[float] = None):
        """Start the background sampler.  Each source is polled at its
        ``interval_hint`` when set, else ``interval_s`` (default: TTL)."""
        if self._thread is not None and self._thread.is_alive():
            return
        default = interval_s if interval_s is not None else self.ttl_s
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                now = time.monotonic()
                with self._lock:
                    entries = list(self._entries.values())
                next_due = default
                for entry in entries:
                    hint = getattr(entry.source, "interval_hint", None)
                    period = hint if hint is not None else default
                    age = (now - entry.collected_at
                           if entry.collected_at is not None else None)
                    if age is None or age >= period:
                        try:
                            self._collect(entry, skip_if_fresh_within=period)
                        except Exception:
                            pass      # counted in stats.errors; keep sampling
                        age = 0.0
                    next_due = min(next_due, max(period - age, 0.0))
                self._stop.wait(max(next_due, 0.01))

        self._thread = threading.Thread(target=loop, name="telemetry-bus",
                                        daemon=True)
        self._thread.start()

    def stop(self, timeout: float = 5.0):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.stop()


# --------------------------------------------------------------------------
# Job-side publish hook (trainer / server -> registry -> live/jobs sources)
# --------------------------------------------------------------------------


def publish_step_utilization(job_name: str, *, model_flops_per_step: float,
                             step_time_s: float, peak_flops: float,
                             n_devices: int = 1, hbm_used_gb: float = 0.0,
                             hbm_total_gb: float = 0.0, registry=None):
    """Hook called by the trainer/server after each (timed) step.

    Publishes the step's achieved utilization into the in-process job
    registry (which the ``live`` and ``jobs`` sources read), so jobs
    self-report instead of being probed via privileged ssh+nvidia-smi —
    the paper's latency complaint, solved at the source.
    """
    from repro.core.collector import DeviceUtilization, JaxJobRegistry

    duty = 0.0
    if step_time_s > 0 and peak_flops > 0:
        duty = model_flops_per_step / step_time_s / (peak_flops * n_devices)
    reg = registry or JaxJobRegistry.global_registry()
    reg.publish(job_name, DeviceUtilization(
        n_devices=n_devices, n_active=n_devices, duty_cycle=duty,
        hbm_total_gb=hbm_total_gb, hbm_used_gb=hbm_used_gb,
        step_time_s=step_time_s,
        achieved_flops=model_flops_per_step / max(step_time_s, 1e-9)))

"""Metric sources — the uniform "where snapshots come from" layer.

Everything that can produce a :class:`ClusterSnapshot` is a
:class:`MetricSource` (DESIGN.md §5): the cluster simulator, the local
host, the in-process JAX job registry, a TSV archive replay, and a
multi-cluster fan-out that merges N child sources.  Consumers
(:class:`~repro.monitor.bus.TelemetryBus`, the CLI, the archiver, the
weekly analysis) only ever see the protocol, so adding a new vantage
point — a remote cluster, a Prometheus scrape — is one class, not a CLI
rewrite.

Sources are constructed by name through :class:`SourceRegistry`; the
default registry knows ``sim``, ``live``, ``jobs``, ``archive`` and
``remote`` (an LLload daemon on another host, :mod:`repro.daemon`).
"""
from __future__ import annotations

import concurrent.futures
import os
import threading
import time
from typing import (Callable, Dict, Iterable, List, Optional, Protocol,
                    Sequence, runtime_checkable)

from repro.core.metrics import (ClusterSnapshot, JobRecord, NodeSnapshot,
                                rows_from_tsv)


@runtime_checkable
class MetricSource(Protocol):
    """One vantage point that can be snapshotted.

    ``interval_hint`` (seconds) tells pollers how often a fresh snapshot
    is worth collecting; ``None`` means "poller's choice".
    """

    name: str
    interval_hint: Optional[float]

    def snapshot(self) -> ClusterSnapshot: ...


# --------------------------------------------------------------------------
# Simulator
# --------------------------------------------------------------------------


class SimSource:
    """Adapter over :class:`repro.cluster.simulator.ClusterSim`.

    ``advance_s`` > 0 advances simulated time on every poll so watch mode
    shows the cluster evolving; 0 keeps the sim frozen (one-shot queries,
    and the byte-identical legacy CLI path).
    """

    def __init__(self, sim, *, advance_s: float = 0.0,
                 name: Optional[str] = None,
                 interval_hint: Optional[float] = None):
        self.sim = sim
        self.advance_s = advance_s
        self.name = name or sim.cluster
        self.interval_hint = interval_hint

    def snapshot(self) -> ClusterSnapshot:
        if self.advance_s > 0:
            self.sim.run_until(self.sim.t + self.advance_s)
        return self.sim.snapshot()


# --------------------------------------------------------------------------
# Local host + in-process JAX jobs
# --------------------------------------------------------------------------


class LocalHostSource:
    """This host (CPU/mem via /proc + psutil, devices via the registry)."""

    def __init__(self, username: Optional[str] = None,
                 cluster: str = "local", interval_hint: float = 5.0):
        from repro.core.collector import LocalHostCollector

        self._collector = LocalHostCollector(username=username,
                                             cluster=cluster)
        self.name = cluster
        self.interval_hint = interval_hint

    def snapshot(self) -> ClusterSnapshot:
        return self._collector.snapshot()


class RegistrySource:
    """The in-process JAX job registry as its own pseudo-cluster.

    One node per published job (hostname == job name) carrying the
    self-reported device metrics — the "what are my jobs doing right now"
    view without any host metrics mixed in.
    """

    def __init__(self, registry=None, *, name: str = "jobs",
                 interval_hint: float = 1.0):
        from repro.core.collector import JaxJobRegistry

        self._registry = registry or JaxJobRegistry.global_registry()
        self.name = name
        self.interval_hint = interval_hint

    def snapshot(self) -> ClusterSnapshot:
        entries = self._registry.entries()
        nodes: Dict[str, NodeSnapshot] = {}
        jobs: List[JobRecord] = []
        user = os.environ.get("USER", "user")
        for i, (job_name, util) in enumerate(sorted(entries.items())):
            nodes[job_name] = NodeSnapshot(
                hostname=job_name, cores_total=os.cpu_count() or 1,
                cores_used=0, load=0.0, mem_total_gb=0.0, mem_used_gb=0.0,
                gpus_total=util.n_devices, gpus_used=util.n_active,
                gpu_load=util.duty_cycle,
                gpu_mem_total_gb=util.hbm_total_gb,
                gpu_mem_used_gb=util.hbm_used_gb)
            jobs.append(JobRecord(
                job_id=i + 1, username=user, name=job_name,
                nodes=[job_name], cores_per_node=0,
                gpus_per_node=util.n_devices, start_time=0.0))
        return ClusterSnapshot(self.name, time.time(), nodes, jobs,
                               {user: f"{user}@local"})


# --------------------------------------------------------------------------
# Archive replay
# --------------------------------------------------------------------------


class ArchiveSource:
    """Replay archived ``--tsv`` rows as a sequence of snapshots.

    Rows (from one or more daily TSV files) are grouped by timestamp into
    frames; each ``snapshot()`` call returns the next frame, so the bus /
    watch mode can scrub through history exactly as if it were live.
    After the last frame the source holds it (or loops when
    ``loop=True``).

    ``interval_hint`` stays ``None``: every poll yields a new frame, so
    the poller picks the replay pace (advertising the archive's 15-min
    snapshot-time cadence as a *wall-clock* hint would freeze replay).
    The original cadence is exposed as ``cadence_s``.
    """

    def __init__(self, root_or_files, *, cluster: Optional[str] = None,
                 loop: bool = False, name: Optional[str] = None):
        if isinstance(root_or_files, str):
            # accept a flat dir of TSVs or a SnapshotArchive root with
            # per-cluster subdirectories
            files = sorted(
                os.path.join(dirpath, f)
                for dirpath, _, fnames in os.walk(root_or_files)
                for f in fnames if f.endswith(".tsv"))
        else:
            files = list(root_or_files)
        rows: List[dict] = []
        for path in files:
            with open(path) as f:
                rows.extend(rows_from_tsv(f.read()))
        self._frames = self._group(rows, cluster)
        self.loop = loop
        self._pos = 0
        first = self._frames[0].cluster if self._frames else "archive"
        self.name = name or (cluster or first)
        self.interval_hint = None
        self.cadence_s = self._infer_interval()

    # ------------------------------------------------------------- build
    @staticmethod
    def _group(rows: Sequence[dict], cluster: Optional[str]
               ) -> List[ClusterSnapshot]:
        # group per (timestamp, cluster) so a multi-cluster archive root
        # never mixes clusters inside one frame (hostname collisions would
        # silently overwrite nodes); same-timestamp frames from different
        # clusters are then merged with collision qualification.
        by_key: Dict[tuple, List[dict]] = {}
        for r in rows:
            if cluster is not None and r["cluster"] != cluster:
                continue
            by_key.setdefault((r["timestamp"], r["cluster"]), []).append(r)
        per_cluster: Dict[float, List[ClusterSnapshot]] = {}
        for ts, cname in sorted(by_key):
            frame_rows = by_key[(ts, cname)]
            nodes: Dict[str, NodeSnapshot] = {}
            by_user: Dict[str, List[dict]] = {}
            for r in frame_rows:
                nodes[r["hostname"]] = NodeSnapshot(
                    hostname=r["hostname"],
                    cores_total=r["cores_total"],
                    cores_used=r["cores_used"], load=r["load"],
                    mem_total_gb=r["mem_total_gb"],
                    mem_used_gb=r["mem_used_gb"],
                    gpus_total=r["gpus_total"], gpus_used=r["gpus_used"],
                    gpu_load=r["gpu_load"],
                    gpu_mem_total_gb=r["gpu_mem_total_gb"],
                    gpu_mem_used_gb=r["gpu_mem_used_gb"])
                by_user.setdefault(r["username"], []).append(r)
            jobs = []
            for i, (user, urows) in enumerate(sorted(by_user.items())):
                jobs.append(JobRecord(
                    job_id=i + 1, username=user,
                    name=f"{user}-replay",
                    nodes=[r["hostname"] for r in urows],
                    cores_per_node=urows[0]["cores_used"],
                    job_type=urows[0]["jobtype"],
                    gpus_per_node=urows[0]["gpus_used"],
                    start_time=ts))
            per_cluster.setdefault(ts, []).append(
                ClusterSnapshot(cname, ts, nodes, jobs))
        return [snaps[0] if len(snaps) == 1 else merge_snapshots(snaps)
                for _, snaps in sorted(per_cluster.items())]

    def _infer_interval(self) -> Optional[float]:
        if len(self._frames) < 2:
            return None
        return self._frames[1].timestamp - self._frames[0].timestamp

    # ------------------------------------------------------------- iterate
    def __len__(self) -> int:
        return len(self._frames)

    def rewind(self):
        self._pos = 0

    def frames(self) -> List[ClusterSnapshot]:
        return list(self._frames)

    def snapshot(self) -> ClusterSnapshot:
        if not self._frames:
            raise ValueError(f"archive source {self.name!r} is empty")
        snap = self._frames[min(self._pos, len(self._frames) - 1)]
        if self._pos < len(self._frames) - 1:
            self._pos += 1
        elif self.loop:
            self._pos = 0
        return snap


# --------------------------------------------------------------------------
# Multi-cluster fan-out
# --------------------------------------------------------------------------


class MultiClusterSource:
    """Fan-out over N child sources with merged snapshots.

    ``snapshot()`` collects every child concurrently (one thread each —
    the paper's ssh fan-out latency lesson: never serialize per-cluster
    collection).  A child that raises keeps serving its last good
    snapshot and is tracked as stale; :meth:`staleness` and
    :meth:`last_error` expose per-source health.  Hostname collisions
    across children are disambiguated as ``cluster:host``.

    ``max_staleness_s`` bounds how long a failing child may keep serving
    its last good snapshot: beyond the cutoff it is **dropped from the
    merge** (and surfaced via :meth:`stale_children`) instead of
    presenting arbitrarily old nodes as current — the unbounded-staleness
    fix.  ``None`` (the default) preserves the old serve-forever
    behaviour.  A healthy child is never dropped, no matter how old its
    data is allowed to get between polls.
    """

    def __init__(self, sources: Sequence[MetricSource], *,
                 name: Optional[str] = None,
                 timeout_s: Optional[float] = 30.0,
                 max_staleness_s: Optional[float] = None):
        if not sources:
            raise ValueError("MultiClusterSource needs >= 1 child source")
        # llcheck: ignore[LL001] fixed after construction; children manage their own state
        self.sources = list(sources)
        self.name = name or "+".join(s.name for s in self.sources)
        self.timeout_s = timeout_s
        self.max_staleness_s = max_staleness_s
        hints = [s.interval_hint for s in self.sources
                 if s.interval_hint is not None]
        self.interval_hint = min(hints) if hints else None
        self._lock = threading.Lock()
        self._last_good: Dict[str, ClusterSnapshot] = {}  # guarded-by: _lock
        self._last_good_at: Dict[str, float] = {}    # guarded-by: _lock
        self._errors: Dict[str, BaseException] = {}  # guarded-by: _lock
        # one persistent worker per child; a hung child's future stays
        # in-flight and is reused instead of stacking new threads per poll
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=len(self.sources),
            thread_name_prefix=f"fanout-{self.name}")
        # guarded-by: _lock
        self._inflight: Dict[str, concurrent.futures.Future] = {}
        # children dropped from the last merge for exceeding
        # max_staleness_s (name -> seconds stale at drop time)
        self._stale_children: Dict[str, float] = {}  # guarded-by: _lock

    # ------------------------------------------------------------- health
    def staleness(self) -> Dict[str, float]:
        """Seconds since each child last produced a good snapshot."""
        now = time.monotonic()
        with self._lock:
            return {name: now - at
                    for name, at in self._last_good_at.items()}

    def stale_children(self) -> Dict[str, float]:
        """Children excluded from the last merge because their last good
        snapshot aged past ``max_staleness_s`` (name -> seconds stale);
        empty when every child contributed (or no cutoff is set)."""
        with self._lock:
            return dict(self._stale_children)

    def last_error(self, name: str) -> Optional[BaseException]:
        with self._lock:
            return self._errors.get(name)

    # ------------------------------------------------------------ collect
    def _collect_child(self, src: MetricSource) -> Optional[ClusterSnapshot]:
        try:
            snap = src.snapshot()
        except Exception as exc:  # noqa: BLE001 — per-child isolation
            with self._lock:
                self._errors[src.name] = exc
                return self._last_good.get(src.name)
        with self._lock:
            self._last_good[src.name] = snap
            self._last_good_at[src.name] = time.monotonic()
            self._errors.pop(src.name, None)
        return snap

    def snapshot(self) -> ClusterSnapshot:
        futs = {}
        # under the lock: concurrent snapshot() callers racing on the
        # in-flight table would submit duplicate collections for a hung
        # child — exactly the thread-stacking the table exists to prevent
        with self._lock:
            for src in self.sources:
                prev = self._inflight.get(src.name)
                if prev is not None and not prev.done():
                    futs[src.name] = prev  # child still hung: don't stack
                else:
                    futs[src.name] = self._pool.submit(
                        self._collect_child, src)
                self._inflight[src.name] = futs[src.name]
        # one overall deadline for the whole fan-out, not N sequential waits
        concurrent.futures.wait(futs.values(), timeout=self.timeout_s)
        snaps = []
        for src in self.sources:
            fut = futs[src.name]
            if fut.done():
                snaps.append(fut.result())
            else:
                # hung child: serve its last good snapshot, keep the merge
                # alive (isolation promise); its future stays in-flight
                with self._lock:
                    self._errors[src.name] = TimeoutError(
                        f"collection exceeded {self.timeout_s}s")
                    snaps.append(self._last_good.get(src.name))
        # bounded staleness: a *failing* child whose fallback snapshot
        # has aged past the cutoff is dropped from the merge instead of
        # masquerading as current data
        if self.max_staleness_s is not None:
            now = time.monotonic()
            stale: Dict[str, float] = {}
            with self._lock:
                for i, src in enumerate(self.sources):
                    if snaps[i] is None or src.name not in self._errors:
                        continue
                    at = self._last_good_at.get(src.name)
                    age = (now - at) if at is not None else float("inf")
                    if age > self.max_staleness_s:
                        snaps[i] = None
                        stale[src.name] = age
                self._stale_children = stale
        else:
            with self._lock:
                self._stale_children = {}
        good = [(src, snap) for src, snap in zip(self.sources, snaps)
                if snap is not None]
        if not good:
            with self._lock:
                errors = {n: str(e) for n, e in self._errors.items()}
            raise RuntimeError(
                f"all {len(self.sources)} child sources failed: "
                f"{errors}")
        return merge_snapshots([s for _, s in good], name=self.name)


def merge_snapshots(snaps: Sequence[ClusterSnapshot], *,
                    name: Optional[str] = None) -> ClusterSnapshot:
    """Merge per-cluster snapshots into one cross-cluster view.

    Hostnames that appear in more than one child are qualified as
    ``cluster:host`` (job node lists are renamed consistently); unique
    hostnames keep their short names so single-cluster behaviour is
    unchanged.
    """
    if len(snaps) == 1 and name is None:
        return snaps[0]
    seen: Dict[str, int] = {}
    for s in snaps:
        for h in s.nodes:
            seen[h] = seen.get(h, 0) + 1
    nodes: Dict[str, NodeSnapshot] = {}
    jobs: List[JobRecord] = []
    emails: Dict[str, str] = {}
    for s in snaps:
        rename = {h: (f"{s.cluster}:{h}" if seen[h] > 1 else h)
                  for h in s.nodes}
        for h, node in s.nodes.items():
            nodes[rename[h]] = (
                node if rename[h] == h else
                _renamed_node(node, rename[h]))
        for job in s.jobs:
            new_nodes = [rename.get(h, h) for h in job.nodes]
            jobs.append(job if new_nodes == job.nodes else
                        _renamed_job(job, new_nodes))
        emails.update(s.user_emails)
    return ClusterSnapshot(
        cluster=name or "+".join(s.cluster for s in snaps),
        timestamp=max(s.timestamp for s in snaps),
        nodes=nodes, jobs=jobs, user_emails=emails)


def _renamed_node(node: NodeSnapshot, hostname: str) -> NodeSnapshot:
    import dataclasses
    return dataclasses.replace(node, hostname=hostname)


def _renamed_job(job: JobRecord, nodes: List[str]) -> JobRecord:
    import dataclasses
    return dataclasses.replace(job, nodes=nodes)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------


class SourceRegistry:
    """Named source factories — the CLI (and anything else) builds sources
    by name instead of hard-coding an if/else per kind."""

    def __init__(self):
        self._factories: Dict[str, Callable[..., MetricSource]] = {}

    def register(self, name: str,
                 factory: Callable[..., MetricSource]) -> None:
        self._factories[name] = factory

    def names(self) -> List[str]:
        return sorted(self._factories)

    def create(self, name: str, **kwargs) -> MetricSource:
        if name not in self._factories:
            raise KeyError(
                f"unknown source {name!r}; registered: {self.names()}")
        return self._factories[name](**kwargs)


def _make_sim_source(*, cluster: str = "txgreen", seed: int = 0,
                     warmup_s: float = 3600.0, advance_s: float = 0.0,
                     n_cpu: int = 64, n_gpu: int = 16) -> SimSource:
    """The paper's LLSC-like simulated cluster, scenario-populated.

    Defaults reproduce the legacy ``--source sim`` CLI path exactly
    (seeded scenario, one simulated hour of warmup, frozen time).
    """
    import random as _random

    from repro.cluster.workloads import make_llsc_sim, paper_scenario

    sim = make_llsc_sim(n_cpu, n_gpu, cluster=cluster)
    paper_scenario(sim, _random.Random(seed))
    sim.run_until(warmup_s)
    return SimSource(sim, advance_s=advance_s, name=cluster)


def _make_live_source(*, cluster: str = "local",
                      username: Optional[str] = None) -> LocalHostSource:
    return LocalHostSource(username=username, cluster=cluster)


def _make_jobs_source(*, cluster: str = "jobs") -> RegistrySource:
    return RegistrySource(name=cluster)


def _make_archive_source(*, root: str, cluster: Optional[str] = None,
                         loop: bool = False) -> ArchiveSource:
    return ArchiveSource(root, cluster=cluster, loop=loop)


def _make_remote_source(*, url: str, cluster: Optional[str] = None,
                        timeout_s: float = 10.0, stream: bool = False,
                        stale_after_s: float = 10.0):
    """An LLload daemon on another host (``--source remote --url ...``).

    ``stream=True`` (what ``--watch`` and daemon fan-in pass) subscribes
    to the daemon's ``/stream`` push channel instead of polling
    ``/snapshot`` per collection; old daemons without the endpoint fall
    back to polling automatically.

    Lazy import: the daemon package depends on this module, not the
    other way around.
    """
    from repro.daemon.client import RemoteSource

    return RemoteSource(url, name=cluster, timeout_s=timeout_s,
                        stream=stream, stale_after_s=stale_after_s)


_DEFAULT_REGISTRY = SourceRegistry()
_DEFAULT_REGISTRY.register("sim", _make_sim_source)
_DEFAULT_REGISTRY.register("live", _make_live_source)
_DEFAULT_REGISTRY.register("jobs", _make_jobs_source)
_DEFAULT_REGISTRY.register("archive", _make_archive_source)
_DEFAULT_REGISTRY.register("remote", _make_remote_source)


def default_registry() -> SourceRegistry:
    return _DEFAULT_REGISTRY


def build_source(name: str, *, clusters: Optional[Sequence[str]] = None,
                 registry: Optional[SourceRegistry] = None,
                 **kwargs) -> MetricSource:
    """Build one source by name, fanning out over ``clusters`` when more
    than one is requested (``--cluster a,b`` => MultiClusterSource)."""
    registry = registry or default_registry()
    clusters = [c for c in (clusters or []) if c]
    if len(clusters) <= 1:
        if clusters:
            kwargs.setdefault("cluster", clusters[0])
        return registry.create(name, **kwargs)
    children = [registry.create(name, cluster=c, **kwargs)
                for c in clusters]
    return MultiClusterSource(children)

"""Streaming watch mode (``LLload --watch [--interval S]``).

A render loop over the :class:`~repro.monitor.bus.TelemetryBus`: the
background sampler collects at each source's cadence while the loop
re-renders from *cached* reads at the display interval — refreshing the
terminal faster than the source is polled costs nothing (the acceptance
property: snapshot() calls < reads).
"""
from __future__ import annotations

import dataclasses
import sys
import time
from typing import Callable, Optional, TextIO

from repro.core.metrics import ClusterSnapshot

from repro.monitor.bus import TelemetryBus

Renderer = Callable[[ClusterSnapshot], str]


@dataclasses.dataclass
class WatchStats:
    frames: int = 0
    reads: int = 0
    collections: int = 0


def frame_header(frame: int, snap: ClusterSnapshot, bus: TelemetryBus,
                 name: Optional[str] = None) -> str:
    trend = bus.load_trend(name)
    stats = bus.stats(name)
    arrow = "+" if trend >= 0 else ""
    return (f"=== LLload watch | frame {frame} | cluster {snap.cluster} | "
            f"t={snap.timestamp:.0f} | trend {arrow}{trend:.4f}/s | "
            f"reads {stats.reads} / collections {stats.collections} ===")


def watch(bus: TelemetryBus, render: Renderer, *,
          source_name: Optional[str] = None,
          interval_s: float = 2.0,
          max_frames: Optional[int] = None,
          poll_interval_s: Optional[float] = None,
          out: TextIO = None,
          sleep: Callable[[float], None] = time.sleep) -> WatchStats:
    """Run the watch loop; returns per-run stats.

    The sampler polls at ``poll_interval_s`` (default 3x the display
    interval, so intermediate frames are served from cache);
    ``max_frames=None`` streams until KeyboardInterrupt.
    """
    out = out if out is not None else sys.stdout
    interval_s = max(interval_s, 0.0)
    if poll_interval_s is None:
        poll_interval_s = 3.0 * interval_s
    # floor the sampler period so --interval 0 degrades to "render as fast
    # as you like" rather than hammering the source in a busy loop
    poll_interval_s = max(poll_interval_s, 0.05)
    # cached reads stay valid for a full sampler period (restored on exit —
    # the bus may be shared with other consumers)
    saved_ttl = bus.ttl_s
    bus.ttl_s = max(bus.ttl_s, poll_interval_s)
    ws = WatchStats()
    base = bus.stats(source_name)      # report deltas over this run only
    bus.start(poll_interval_s)
    try:
        frame = 0
        while max_frames is None or frame < max_frames:
            snap = bus.read(source_name)
            frame += 1
            ws.frames = frame
            out.write(frame_header(frame, snap, bus, source_name) + "\n")
            out.write(render(snap) + "\n")
            out.flush()
            if max_frames is not None and frame >= max_frames:
                break
            sleep(interval_s)
    except KeyboardInterrupt:
        pass
    except BrokenPipeError:
        pass      # downstream pager/head closed the stream mid-frame
    finally:
        bus.stop()
        bus.ttl_s = saved_ttl
        stats = bus.stats(source_name)
        ws.reads = stats.reads - base.reads
        ws.collections = stats.collections - base.collections
    return ws

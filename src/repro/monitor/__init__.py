"""repro.monitor — the pluggable telemetry layer (DESIGN.md §5).

Every snapshot producer is a :class:`MetricSource`; the
:class:`TelemetryBus` polls them, caches, streams, and computes deltas;
``watch()`` renders live.  Jobs push via :func:`publish_step_utilization`.
"""
from repro.monitor.bus import (SourceStats, TelemetryBus,
                               publish_step_utilization)
from repro.monitor.source import (ArchiveSource, LocalHostSource,
                                  MetricSource, MultiClusterSource,
                                  RegistrySource, SimSource, SourceRegistry,
                                  build_source, default_registry,
                                  merge_snapshots)
from repro.monitor.watch import WatchStats, frame_header, watch

__all__ = [
    "ArchiveSource", "LocalHostSource", "MetricSource", "MultiClusterSource",
    "RegistrySource", "SimSource", "SourceRegistry", "SourceStats",
    "TelemetryBus", "WatchStats", "build_source", "default_registry",
    "frame_header", "merge_snapshots", "publish_step_utilization", "watch",
]

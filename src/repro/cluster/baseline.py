"""Object-based reference scheduler/simulator (the pre-columnar path).

This module preserves the per-node / per-task Python-object
implementation that :class:`repro.cluster.scheduler.Scheduler` and
:meth:`repro.cluster.simulator.ClusterSim.snapshot` replaced with the
columnar :class:`~repro.cluster.fleet.FleetState`.  It exists for two
reasons:

* **equivalence oracle** — property tests drive an
  :class:`ObjectClusterSim` and a columnar ``ClusterSim`` through
  identical submit/step/cancel sequences and assert byte-identical
  snapshots (DESIGN.md §10);
* **benchmark baseline** — ``benchmarks/run.py:bench_sim`` measures the
  columnar speedup against this path (``BENCH_sim.json``).

It is NOT a frozen copy: the scheduling *bug fixes* that shipped with
the columnar rebuild apply here too, so both paths implement the same
semantics —

* multi-GPU fit requires ``gpus_per_task`` *distinct* GPUs under the
  ``tasks_per_gpu`` cap (the old slot-total check could place a 2-GPU
  task on a single GPU with 2 free slots);
* job completion/cancel frees only ``job.hostnames`` instead of
  scanning the whole fleet;
* ``_place`` maintains GPU occupancy incrementally per placement plan
  instead of rebuilding it from every task on the node, per task.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.cluster.fleet import host_seed
from repro.cluster.job import Job, JobSpec, RunningTask
from repro.cluster.node import NodeSpec
from repro.core.metrics import ClusterSnapshot, JobRecord, NodeSnapshot


@dataclasses.dataclass
class NodeState:
    """Mutable per-node state: the spec plus the running-task list."""

    spec: NodeSpec
    tasks: List[RunningTask] = dataclasses.field(default_factory=list)
    exclusive_job: Optional[int] = None

    @property
    def user(self) -> Optional[str]:
        return self.tasks[0].username if self.tasks else None

    @property
    def users(self) -> set:
        return {t.username for t in self.tasks}

    @property
    def cores_used(self) -> int:
        return sum(t.cores for t in self.tasks)

    def gpu_occupancy(self) -> Dict[int, int]:
        occ = {i: 0 for i in range(self.spec.gpus)}
        for t in self.tasks:
            for g in t.gpu_slots:
                occ[g] += 1
        return occ

    def mem_used(self) -> float:
        return sum(t.profile.mem_gb for t in self.tasks)


def gpu_fit_distinct(occ: Dict[int, int], tpg: int, gpt: int,
                     cap: int) -> int:
    """Greedy count of tasks that fit when each needs ``gpt`` *distinct*
    GPUs with at most ``tpg`` tasks per GPU, stopping at ``cap``."""
    if gpt == 1:
        return min(cap, sum(max(0, tpg - c) for c in occ.values()))
    work = dict(occ)
    m = 0
    while m < cap:
        free = [g for g in sorted(work, key=lambda g: (work[g], g))
                if work[g] < tpg]
        if len(free) < gpt:
            break
        for g in free[:gpt]:
            work[g] += 1
        m += 1
    return m


class ObjectScheduler:
    """The pre-columnar Slurm-like scheduler (see module docstring;
    policy semantics are documented on the columnar ``Scheduler``)."""

    def __init__(self, nodes: List[NodeSpec],
                 partitions: Optional[Dict[str, dict]] = None):
        self.nodes: Dict[str, NodeState] = {
            n.hostname: NodeState(n) for n in nodes}
        if partitions is None:
            partitions = {"normal": {"hosts": [n.hostname for n in nodes],
                                     "policy": "whole-node"}}
        self.partitions = partitions
        self.pending: List[Job] = []
        self.running: List[Job] = []
        self.completed: List[Job] = []
        self._next_id = 26140000

    # ------------------------------------------------------------- submit
    def submit(self, spec: JobSpec, now: float) -> Job:
        job = Job(self._next_id, spec, submit_time=now)
        self._next_id += 1
        self.pending.append(job)
        return job

    # ----------------------------------------------------------- dispatch
    def _node_fits(self, ns: NodeState, job: Job, tasks: int) -> int:
        """How many tasks of `job` fit on node `ns` right now."""
        spec, jspec = ns.spec, job.spec
        part = self.partitions.get(jspec.partition)
        if part is None or ns.spec.hostname not in part["hosts"]:
            return 0
        if ns.exclusive_job is not None:
            return 0
        if jspec.exclusive and ns.tasks:
            return 0
        policy = part.get("policy", "whole-node")
        if policy == "whole-node" and ns.tasks and ns.user != jspec.username:
            return 0  # per-user whole-node isolation
        free_cores = spec.cores - ns.cores_used
        fit = free_cores // max(jspec.cores_per_task, 1)
        free_mem = spec.mem_gb - ns.mem_used()
        if jspec.profile.mem_gb > 0:
            fit = min(fit, int(free_mem // jspec.profile.mem_gb))
        if jspec.gpus_per_task > 0:
            fit = gpu_fit_distinct(ns.gpu_occupancy(), jspec.tasks_per_gpu,
                                   jspec.gpus_per_task, max(fit, 0))
        return max(0, min(fit, tasks))

    def _place(self, ns: NodeState, job: Job, count: int):
        jspec = job.spec
        occ = ns.gpu_occupancy() if jspec.gpus_per_task > 0 else None
        for _ in range(count):
            gpu_slots = ()
            if occ is not None:
                # round-robin: least-occupied GPUs first (paper's
                # overloading), occupancy carried across tasks
                order = sorted(occ, key=lambda g: occ[g])
                chosen = [g for g in order
                          if occ[g] < jspec.tasks_per_gpu][
                              : jspec.gpus_per_task]
                if len(chosen) < jspec.gpus_per_task:
                    raise AssertionError(
                        f"{ns.spec.hostname}: {len(chosen)} distinct free "
                        f"GPUs for a {jspec.gpus_per_task}-GPU task")
                for g in chosen:
                    occ[g] += 1
                gpu_slots = tuple(chosen)
            ns.tasks.append(RunningTask(
                job.job_id, jspec.username, ns.spec.hostname, jspec.profile,
                jspec.cores_per_task, gpu_slots))
        if jspec.exclusive:
            ns.exclusive_job = job.job_id
        if ns.spec.hostname not in job.hostnames:
            job.hostnames.append(ns.spec.hostname)

    def _try_dispatch(self, job: Job, now: float) -> bool:
        remaining = job.spec.n_tasks
        plan = []
        # Prefer nodes this user already holds (packs whole nodes densely).
        def keyfn(ns):
            return (0 if ns.user == job.spec.username and ns.tasks else
                    (1 if not ns.tasks else 2), ns.spec.hostname)
        for ns in sorted(self.nodes.values(), key=keyfn):
            if remaining <= 0:
                break
            fit = self._node_fits(ns, job, remaining)
            if fit > 0:
                plan.append((ns, fit))
                remaining -= fit
        if remaining > 0:
            return False
        for ns, count in plan:
            self._place(ns, job, count)
        job.state = "R"
        job.start_time = now
        self.running.append(job)
        return True

    # ------------------------------------------------------------- cancel
    def _free(self, job: Job):
        """Free a job's slots on the hosts it actually ran on
        (``job.hostnames``) — not a whole-fleet scan."""
        for host in job.hostnames:
            ns = self.nodes[host]
            ns.tasks = [t for t in ns.tasks if t.job_id != job.job_id]
            if ns.exclusive_job == job.job_id:
                ns.exclusive_job = None

    def cancel(self, job_id: int) -> Optional[Job]:
        """Cancel a pending or running job (state ``CA``), freeing any
        node slots it holds; ``None`` if not pending/running."""
        for i, job in enumerate(self.pending):
            if job.job_id == job_id:
                job.state = "CA"
                return self.pending.pop(i)
        for i, job in enumerate(self.running):
            if job.job_id == job_id:
                job.state = "CA"
                self.running.pop(i)
                self._free(job)
                return job
        return None

    # ---------------------------------------------------------------- tick
    def tick(self, now: float):
        # completions
        still = []
        for job in self.running:
            if job.start_time is not None and \
                    now - job.start_time >= job.spec.duration_s:
                job.state = "CG"
                job.end_time = now
                self._free(job)
                self.completed.append(job)
            else:
                still.append(job)
        self.running = still
        # dispatch FIFO
        still_pending = []
        for job in self.pending:
            if not self._try_dispatch(job, now):
                still_pending.append(job)
        self.pending = still_pending

    # ---------------------------------------------------------- invariants
    def check_whole_node_invariant(self) -> List[str]:
        """Returns violations: whole-node partition nodes with >1 user."""
        bad = []
        shared_hosts = set()
        for part in self.partitions.values():
            if part.get("policy") == "shared":
                shared_hosts.update(part["hosts"])
        for host, ns in self.nodes.items():
            if host in shared_hosts:
                continue
            if len(ns.users) > 1:
                bad.append(host)
        return bad


def object_snapshot(sim) -> ClusterSnapshot:
    """The pre-columnar per-node/per-task snapshot loop, over any sim
    whose scheduler exposes object ``NodeState``s (the byte-identity
    oracle for ``ClusterSim.snapshot``)."""
    nodes: Dict[str, NodeSnapshot] = {}
    for host, ns in sim.sched.nodes.items():
        spec = ns.spec
        load = 0.0
        gpu_duty = 0.0
        gpu_mem = 0.0
        gpus_used = set()
        hseed = host_seed(host)
        for task in ns.tasks:
            load += task.profile.cpu_load(sim.t, hseed % 97)
            for g in task.gpu_slots:
                gpus_used.add(g)
            gpu_duty += task.profile.gpu_load(sim.t, hseed % 89)
            gpu_mem += task.profile.gpu_mem_gb
        # duty cycle saturates at 1.0 per device (the overloading payoff:
        # several low-duty tasks sum toward full utilization)
        gpu_load = 0.0
        if spec.gpus > 0 and gpus_used:
            gpu_load = min(1.0, gpu_duty / max(len(gpus_used), 1))
        nodes[host] = NodeSnapshot(
            hostname=host,
            cores_total=spec.cores,
            cores_used=min(ns.cores_used, spec.cores),
            load=load,
            mem_total_gb=spec.mem_gb,
            mem_used_gb=min(ns.mem_used(), spec.mem_gb),
            gpus_total=spec.gpus,
            gpus_used=len(gpus_used),
            gpu_load=gpu_load,
            gpu_mem_total_gb=spec.gpus * spec.gpu_mem_gb,
            gpu_mem_used_gb=min(gpu_mem, spec.gpus * spec.gpu_mem_gb),
        )
    jobs = []
    for job in sim.sched.running:
        s = job.spec
        jobs.append(JobRecord(
            job_id=job.job_id, username=s.username, name=s.name,
            nodes=list(job.hostnames), cores_per_node=s.cores_per_task,
            state="R", job_type=s.job_type,
            gpus_per_node=s.gpus_per_task, gpu_request=s.gpu_request,
            start_time=job.start_time or 0.0, partition=s.partition,
            mem_per_node_gb=s.profile.mem_gb,
            submit_time=job.submit_time or 0.0))
    return ClusterSnapshot(sim.cluster, sim.t, nodes, jobs,
                           dict(sim.user_emails))


class ObjectClusterSim:
    """Object-path twin of :class:`~repro.cluster.simulator.ClusterSim`
    (same control API, :class:`ObjectScheduler` + ``object_snapshot``)."""

    def __init__(self, nodes: List[NodeSpec], *, cluster: str = "txgreen",
                 partitions: Optional[dict] = None, seed: int = 0):
        self.cluster = cluster
        self.sched = ObjectScheduler(nodes, partitions)
        self.t = 0.0
        self.seed = seed
        self.user_emails: Dict[str, str] = {}

    def submit(self, spec: JobSpec, *, now: Optional[float] = None) -> int:
        self.user_emails.setdefault(spec.username,
                                    f"{spec.username}@ll.mit.edu")
        return self.sched.submit(spec, self.t if now is None else now).job_id

    def step(self, dt: float = 60.0):
        self.t += dt
        self.sched.tick(self.t)

    def run_until(self, t: float, dt: float = 60.0):
        while self.t < t:
            self.step(min(dt, t - self.t))

    def snapshot(self) -> ClusterSnapshot:
        return object_snapshot(self)

"""Synthetic workload generators reproducing the paper's case studies.

Each factory returns JobSpecs whose monitored signature matches a figure:
  * Fig 7  — low GPU duty (0.2..0.45), small GPU memory: overloading target
  * Fig 8  — mis-submission: too many cores/task => 1 task per 2-GPU node
  * Fig 10/11 — thread oversubscription and the file-I/O-storm 720-load case
  * Jupyter/debug jobs for the shared partitions (Fig 4 summary block)
"""
from __future__ import annotations

import random
from typing import List

from repro.cluster.job import JobSpec, TaskProfile
from repro.cluster.node import NodeSpec, make_nodes


def llsc_nodes(n_cpu: int = 64, n_gpu: int = 16) -> List[NodeSpec]:
    cpu = make_nodes("d", n_cpu, cores=48, mem_gb=192.0)
    gpu = make_nodes("c", n_gpu, cores=40, mem_gb=384.0, gpus=2,
                     gpu_mem_gb=32.0)
    return cpu + gpu


def ml_training_job(user, tasks=4, gpu_frac=0.85, name="train.sh"):
    return JobSpec(user, name, n_tasks=tasks, cores_per_task=20,
                   gpus_per_task=1, duration_s=86400.0,
                   profile=TaskProfile(threads=8, cpu_activity=0.5,
                                       mem_gb=60.0, gpu_frac=gpu_frac,
                                       gpu_mem_gb=24.0))


def low_gpu_job(user, tasks=4, gpu_frac=0.35, name="supercloud_run.sh"):
    """Fig 7: modest CPU, tiny GPU memory, GPU duty 0.23–0.45."""
    return JobSpec(user, name, n_tasks=tasks, cores_per_task=20,
                   gpus_per_task=1, duration_s=86400.0,
                   profile=TaskProfile(threads=2, cpu_activity=1.0,
                                       mem_gb=63.0, gpu_frac=gpu_frac,
                                       gpu_mem_gb=2.0))


def missubmitted_gpu_job(user, tasks=5, name="run_model.sh"):
    """Fig 8: 40 cores/task on 40-core 2-GPU nodes => one task per node."""
    return JobSpec(user, name, n_tasks=tasks, cores_per_task=40,
                   gpus_per_task=1, duration_s=86400.0,
                   profile=TaskProfile(threads=2, cpu_activity=0.9,
                                       mem_gb=26.0, gpu_frac=0.35,
                                       gpu_mem_gb=3.0))


def fixed_gpu_job(user, tasks=5, name="run_model.sh"):
    """Fig 9: the Fig-8 job after the advisor's fix (20 cores/task)."""
    return JobSpec(user, name, n_tasks=tasks, cores_per_task=20,
                   gpus_per_task=1, duration_s=86400.0,
                   profile=TaskProfile(threads=2, cpu_activity=0.9,
                                       mem_gb=26.0, gpu_frac=0.35,
                                       gpu_mem_gb=3.0))


def overloaded_gpu_job(user, tasks=8, tasks_per_gpu=4,
                       name="overloaded_run.sh"):
    """The paper's remediation: NPPN>1 tasks share each GPU."""
    return JobSpec(user, name, n_tasks=tasks, cores_per_task=5,
                   gpus_per_task=1, tasks_per_gpu=tasks_per_gpu,
                   duration_s=86400.0,
                   profile=TaskProfile(threads=2, cpu_activity=1.0,
                                       mem_gb=20.0, gpu_frac=0.35,
                                       gpu_mem_gb=2.0))


def fragmented_job(user, tasks=1, name="one_task.sh"):
    """Fleet fragmentation: a tiny exclusive job that pins a whole node
    at a few busy cores.  Submitted in bulk these fragment the fleet —
    the ``fleet_fragmentation`` rule's target; consolidation (dropping
    ``exclusive``) lets the whole batch share a couple of nodes."""
    return JobSpec(user, name, n_tasks=tasks, cores_per_task=4,
                   duration_s=86400.0, exclusive=True,
                   profile=TaskProfile(threads=4, cpu_activity=0.9,
                                       mem_gb=16.0))


def thread_oversubscribed_job(user, tasks=2, name="multiproc.py"):
    """Fig 10: each task spawns as many threads as the node has cores; with
    2 tasks per node the runnable-thread count is ~2x cores (norm ~2.2)."""
    return JobSpec(user, name, n_tasks=tasks, cores_per_task=20,
                   duration_s=86400.0,
                   profile=TaskProfile(threads=52, cpu_activity=1.0,
                                       mem_gb=60.0))


def io_storm_job(user, tasks=2, name="supercloud_run.sh"):
    """Fig 11 root cause: concurrent file-I/O storm => load ~720 on 48 cores."""
    return JobSpec(user, name, n_tasks=tasks, cores_per_task=48,
                   duration_s=86400.0,
                   profile=TaskProfile(threads=720, cpu_activity=1.0,
                                       mem_gb=190.0, jitter=0.05))


def cpu_sim_job(user, tasks=8, name="cfd_solver"):
    return JobSpec(user, name, n_tasks=tasks, cores_per_task=48,
                   duration_s=86400.0,
                   profile=TaskProfile(threads=48, cpu_activity=0.95,
                                       mem_gb=150.0))


def underutilized_cpu_job(user, tasks=6, name="sweep.sh"):
    return JobSpec(user, name, n_tasks=tasks, cores_per_task=48,
                   duration_s=86400.0,
                   profile=TaskProfile(threads=4, cpu_activity=0.8,
                                       mem_gb=24.0))


def jupyter_job(user, gpu=False):
    prof = TaskProfile(threads=1, cpu_activity=0.05, mem_gb=8.0,
                       gpu_frac=0.05 if gpu else 0.0,
                       gpu_mem_gb=2.0 if gpu else 0.0)
    return JobSpec(user, "jupyter", n_tasks=1, cores_per_task=2,
                   gpus_per_task=1 if gpu else 0, duration_s=86400.0,
                   profile=prof, partition="jupyter", job_type="jupyter",
                   gpu_request="gres:gpu:volta:1" if gpu else "")


def make_llsc_sim(n_cpu: int = 64, n_gpu: int = 16, *, seed: int = 0,
                  cluster: str = "txgreen"):
    """Cluster with whole-node normal partition + shared jupyter/debug
    partitions (the paper's fix for short/interactive jobs)."""
    from repro.cluster.simulator import ClusterSim

    nodes = llsc_nodes(n_cpu, n_gpu)
    hosts = [n.hostname for n in nodes]
    cpu_hosts = hosts[:n_cpu]
    gpu_hosts = hosts[n_cpu:]
    jupyter_hosts = cpu_hosts[:2] + gpu_hosts[:1]
    normal_hosts = [h for h in hosts if h not in jupyter_hosts]
    partitions = {
        "normal": {"hosts": normal_hosts, "policy": "whole-node"},
        "jupyter": {"hosts": jupyter_hosts, "policy": "shared"},
        "debug": {"hosts": jupyter_hosts, "policy": "shared"},
    }
    return ClusterSim(nodes, cluster=cluster, partitions=partitions,
                      seed=seed)


def paper_scenario(sim, rng: random.Random):
    """Populate a sim with the paper's mixture (used by tests/benchmarks)."""
    sim.submit(ml_training_job("ab12345", tasks=6))
    sim.submit(low_gpu_job("va67890", tasks=5))
    sim.submit(missubmitted_gpu_job("rs12345", tasks=3))
    sim.submit(thread_oversubscribed_job("user01", tasks=2))
    sim.submit(io_storm_job("user02", tasks=2))
    sim.submit(cpu_sim_job("cd67890", tasks=8))
    sim.submit(underutilized_cpu_job("jk12345", tasks=6))
    for i, (u, g) in enumerate([("ch12345", False), ("cd67890", False),
                                ("no12345", True), ("pq67890", True),
                                ("lm67890", False)]):
        sim.submit(jupyter_job(u, gpu=g))
    return sim

"""FleetState — structure-of-arrays cluster state for LLSC-scale fleets.

The object-based :class:`~repro.cluster.baseline.ObjectScheduler` keeps a
``NodeState`` with a Python ``RunningTask`` list per node; every fit,
placement, completion and snapshot walks those lists, which caps
campaigns at toy fleet sizes.  ``FleetState`` keeps the same state as
numpy columns — node specs, core/memory/GPU-slot occupancy, and one task
table (node / job / user / profile / cores / GPU-bitmask columns) — so
the scheduler and simulator can evaluate *whole-fleet* questions
("which nodes fit this job?", "what is every node's load right now?")
as array expressions instead of per-node Python loops (DESIGN.md §10).

Bitwise equivalence with the object path is a design constraint, not an
accident (the CLI's golden fixtures pin flagless output byte-for-byte):

* per-node float reductions (memory, CPU load, GPU duty/memory) are
  evaluated with :meth:`FleetState._seg_sum_ordered`, a padded
  column-sweep that reproduces Python's sequential ``acc += v`` in task
  insertion order — ``np.add.reduceat`` would pairwise-sum and drift in
  the last ulp;
* per-task duty-cycle curves are evaluated through the *same*
  ``TaskProfile.cpu_load`` / ``gpu_load`` Python methods, once per
  unique ``(profile, host-seed)`` pair (there are at most
  ``profiles × 97`` of them), then gathered per task with one indexed
  load;
* GPU slots are assigned by a vectorized water-fill that provably emits
  the same (least-occupied, lowest-index-first) pick sequence as the
  object path's per-task ``sorted(occ)`` loop.

Integer state (cores used, per-GPU slot occupancy) is maintained
incrementally — exact in integers — while float aggregates are
recomputed from the task table when read after a mutation (``_cache``),
matching the object path's recompute-on-read semantics.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.cluster.job import JobSpec, TaskProfile
from repro.cluster.node import NodeSpec
from repro.core.metrics import NodeColumns

#: GPU slots are tracked as one int64 bitmask per task.
MAX_GPUS_PER_NODE = 63


def host_seed(hostname: str) -> int:
    """Stable per-host jitter seed (crc32: ``str.__hash__`` is randomized
    per process, which made snapshots non-reproducible)."""
    return zlib.crc32(hostname.encode())


@dataclasses.dataclass
class _DerivedCache:
    """Task-table aggregates recomputed after a mutation (see module doc:
    float state is recompute-on-read, integer state is incremental)."""
    order: np.ndarray          # stable argsort of task rows by node
    occ_nodes: np.ndarray      # node index of each non-empty segment
    starts: np.ndarray         # segment starts into `order`
    counts: np.ndarray         # tasks per non-empty segment
    row: np.ndarray            # per sorted task: its segment row
    pos: np.ndarray            # per sorted task: its position in segment
    width: int                 # max tasks on any one node
    n_tasks: np.ndarray        # per node: alive task count
    first_user: np.ndarray     # per node: user id of earliest task (-1)
    mem_used: np.ndarray       # per node: ordered sum of task mem_gb


class FleetState:
    """Columnar node + task state behind :class:`repro.cluster.scheduler.
    Scheduler` (see module docstring for the layout and the bitwise-
    equivalence strategy)."""

    def __init__(self, specs: Sequence[NodeSpec],
                 partitions: Dict[str, dict]):
        self.specs: List[NodeSpec] = list(specs)
        n = len(self.specs)
        self.n_nodes = n
        self.hostnames: List[str] = [s.hostname for s in self.specs]
        self.host_index: Dict[str, int] = {
            h: i for i, h in enumerate(self.hostnames)}
        self.cores = np.array([s.cores for s in self.specs], np.int64)
        self.mem_gb = np.array([s.mem_gb for s in self.specs], np.float64)
        self.gpus = np.array([s.gpus for s in self.specs], np.int64)
        self.gpu_mem_gb = np.array([s.gpu_mem_gb for s in self.specs],
                                   np.float64)
        self.gpu_mem_total = self.gpus * self.gpu_mem_gb
        seeds = [host_seed(h) for h in self.hostnames]
        self.smod97 = np.array([s % 97 for s in seeds], np.int64)
        self.smod89 = np.array([s % 89 for s in seeds], np.int64)
        # rank of each hostname in Python-string sort order: dispatch
        # tie-breaks sort by hostname, and an integer rank sorts faster
        # than strings while ordering identically
        by_name = sorted(range(n), key=self.hostnames.__getitem__)
        self.hostrank = np.empty(n, np.int64)
        self.hostrank[np.array(by_name, np.int64) if n else []] = \
            np.arange(n, dtype=np.int64)
        self.max_gpus = int(self.gpus.max()) if n else 0
        if self.max_gpus > MAX_GPUS_PER_NODE:
            raise ValueError(
                f"FleetState tracks GPU slots in an int64 bitmask; a node "
                f"with {self.max_gpus} > {MAX_GPUS_PER_NODE} devices is "
                "not representable")
        # --- incremental integer state (exact) ---
        self.occ = np.zeros((n, max(self.max_gpus, 1)), np.int64)
        self.cores_used = np.zeros(n, np.int64)
        self.exclusive_job = np.full(n, -1, np.int64)
        # per-node alive-task count and earliest task's user id, kept
        # incrementally (exact in integers) so the scheduler's
        # small-fleet dispatch path can answer "is this node held, and
        # by whom?" without rebuilding the derived cache
        self.n_tasks_node = np.zeros(n, np.int64)
        self.first_user_node = np.full(n, -1, np.int64)
        self._ntn_list: List[int] = [0] * n
        self._ntn_list_version = 0
        # --- partition membership (static) ---
        self.part_mask: Dict[str, np.ndarray] = {}
        self.shared_mask = np.zeros(n, bool)
        for name, part in partitions.items():
            mask = np.zeros(n, bool)
            for h in part["hosts"]:
                idx = self.host_index.get(h)
                if idx is not None:
                    mask[idx] = True
            self.part_mask[name] = mask
            if part.get("policy") == "shared":
                self.shared_mask |= mask
        # --- task table (amortized append, boolean-mask compaction) ---
        self._cap = 1024
        self.t_node = np.empty(self._cap, np.int64)
        self.t_job = np.empty(self._cap, np.int64)
        self.t_user = np.empty(self._cap, np.int64)
        self.t_prof = np.empty(self._cap, np.int64)
        self.t_cores = np.empty(self._cap, np.int64)
        self.t_gmask = np.empty(self._cap, np.int64)
        self.n_tasks_total = 0
        # --- intern tables ---
        self._user_ids: Dict[str, int] = {}
        self.user_names: List[str] = []
        self._profile_ids: Dict[tuple, int] = {}
        self.profiles: List[TaskProfile] = []
        self._prof_mem = np.empty(0, np.float64)
        self._prof_gpu_mem = np.empty(0, np.float64)
        self._cache: Optional[_DerivedCache] = None
        # per-mod (version, (profile, seed) pairs, inverse) for the duty
        # tables, and the t-independent snapshot columns — both reusable
        # across every snapshot between fleet mutations
        self._duty_keys: Dict[int, tuple] = {}
        self._static_cols: Optional[tuple] = None
        self.version = 0            # bumped on every mutation

    # ------------------------------------------------------------- intern
    def user_id(self, username: str) -> int:
        """Intern ``username`` and return its integer id."""
        uid = self._user_ids.get(username)
        if uid is None:
            uid = len(self.user_names)
            self._user_ids[username] = uid
            self.user_names.append(username)
        return uid

    def profile_id(self, profile: TaskProfile) -> int:
        """Intern a :class:`TaskProfile` by value and return its id."""
        key = (profile.threads, profile.cpu_activity, profile.mem_gb,
               profile.gpu_frac, profile.gpu_mem_gb, profile.jitter)
        pid = self._profile_ids.get(key)
        if pid is None:
            pid = len(self.profiles)
            self._profile_ids[key] = pid
            self.profiles.append(profile)
            self._prof_mem = np.append(self._prof_mem, profile.mem_gb)
            self._prof_gpu_mem = np.append(self._prof_gpu_mem,
                                           profile.gpu_mem_gb)
        return pid

    # ---------------------------------------------------------- mutation
    def _dirty(self):
        self._cache = None
        self.version += 1

    def _grow(self, need: int):
        while self._cap < need:
            self._cap *= 2
        for name in ("t_node", "t_job", "t_user", "t_prof", "t_cores",
                     "t_gmask"):
            old = getattr(self, name)
            new = np.empty(self._cap, old.dtype)
            new[: self.n_tasks_total] = old[: self.n_tasks_total]
            setattr(self, name, new)

    def place(self, idx: int, job, count: int) -> None:
        """Place ``count`` tasks of ``job`` on node ``idx`` (mirrors the
        object path's ``_place``, including its GPU pick order)."""
        jspec: JobSpec = job.spec
        nt = self.n_tasks_total
        if nt + count > self._cap:
            self._grow(nt + count)
        sl = slice(nt, nt + count)
        uid = self.user_id(jspec.username)
        self.t_node[sl] = idx
        self.t_job[sl] = job.job_id
        self.t_user[sl] = uid
        self.t_prof[sl] = self.profile_id(jspec.profile)
        self.t_cores[sl] = jspec.cores_per_task
        if jspec.gpus_per_task > 0:
            self.t_gmask[sl] = self._assign_gpus(idx, jspec, count)
        else:
            self.t_gmask[sl] = 0
        self.n_tasks_total = nt + count
        self.cores_used[idx] += count * jspec.cores_per_task
        if self.n_tasks_node[idx] == 0:
            self.first_user_node[idx] = uid
        self.n_tasks_node[idx] += count
        if jspec.exclusive:
            self.exclusive_job[idx] = job.job_id
        host = self.hostnames[idx]
        if host not in job.hostnames:
            job.hostnames.append(host)
        self._dirty()

    def _assign_gpus(self, idx: int, jspec: JobSpec,
                     count: int) -> np.ndarray:
        """GPU bitmasks for ``count`` tasks placed on node ``idx``,
        matching the object path's per-task "least-occupied GPU first,
        ties by index" round-robin; updates slot occupancy."""
        G = int(self.gpus[idx])
        tpg, gpt = jspec.tasks_per_gpu, jspec.gpus_per_task
        occ_row = self.occ[idx, :G]
        if gpt == 1:
            # Water-fill: repeatedly picking argmin-(occ, index) emits the
            # slot units (level, gpu) in lexicographic (level, gpu) order,
            # so the first `count` entries of that grid ARE the picks.
            lev = np.arange(tpg, dtype=np.int64)[:, None]
            gidx = np.broadcast_to(np.arange(G, dtype=np.int64), (tpg, G))
            valid = lev >= occ_row[None, :]
            picks = gidx[valid][:count]
            if len(picks) < count:
                raise AssertionError(
                    f"GPU water-fill underflow on node {idx}: "
                    f"{len(picks)} slots for {count} tasks")
            occ_row += np.bincount(picks, minlength=G)
            return np.left_shift(np.int64(1), picks)
        masks = np.empty(count, np.int64)
        for i in range(count):
            order = np.argsort(occ_row, kind="stable")
            free = order[occ_row[order] < tpg]
            if len(free) < gpt:
                raise AssertionError(
                    f"node {idx}: {len(free)} distinct free GPUs for a "
                    f"{gpt}-GPU task (fit computation must prevent this)")
            chosen = free[:gpt]
            occ_row[chosen] += 1
            masks[i] = np.bitwise_or.reduce(
                np.left_shift(np.int64(1), chosen))
        return masks

    def free_jobs(self, job_ids: Iterable[int],
                  hostnames: Iterable[str] = ()) -> int:
        """Remove every task of ``job_ids`` (one boolean-mask compaction,
        not a per-node list rebuild) and clear exclusive holds on the
        jobs' recorded ``hostnames``.  Returns tasks freed."""
        ids = set(int(j) for j in job_ids)
        nt = self.n_tasks_total
        if nt and ids:
            if len(ids) == 1:
                rm = self.t_job[:nt] == next(iter(ids))
            else:
                rm = np.isin(self.t_job[:nt],
                             np.array(sorted(ids), np.int64))
            n_rm = int(rm.sum())
        else:
            rm, n_rm = None, 0
        if n_rm:
            nodes_rm = self.t_node[:nt][rm]
            np.subtract.at(self.cores_used, nodes_rm, self.t_cores[:nt][rm])
            masks_rm = self.t_gmask[:nt][rm]
            if masks_rm.any():
                for g in range(self.max_gpus):
                    bit = (masks_rm >> g) & 1
                    if bit.any():
                        np.subtract.at(self.occ[:, g], nodes_rm, bit)
            keep = ~rm
            for name in ("t_node", "t_job", "t_user", "t_prof", "t_cores",
                         "t_gmask"):
                col = getattr(self, name)
                col[: nt - n_rm] = col[:nt][keep]
            self.n_tasks_total = nt - n_rm
            # incremental per-node task counts + earliest-task user; the
            # compaction keeps insertion order, so a node's new earliest
            # task is its first surviving row
            np.subtract.at(self.n_tasks_node, nodes_rm, 1)
            aff = np.unique(nodes_rm)
            self.first_user_node[aff[self.n_tasks_node[aff] == 0]] = -1
            refresh = aff[self.n_tasks_node[aff] > 0]
            if len(refresh):
                tn = self.t_node[: self.n_tasks_total]
                for i in refresh.tolist():
                    rows = np.flatnonzero(tn == i)
                    self.first_user_node[i] = self.t_user[rows[0]]
        for h in hostnames:
            idx = self.host_index.get(h)
            if idx is not None and int(self.exclusive_job[idx]) in ids:
                self.exclusive_job[idx] = -1
        if n_rm or len(ids):
            self._dirty()
        return n_rm

    def n_tasks_node_tolist(self) -> List[int]:
        """``n_tasks_node`` as a plain list (cached per version) — the
        small-fleet dispatch scan reads it per node, and Python-list
        reads are ~3x cheaper than numpy scalar indexing."""
        if self._ntn_list_version != self.version or self._ntn_list is None:
            self._ntn_list = self.n_tasks_node.tolist()
            self._ntn_list_version = self.version
        return self._ntn_list

    # ------------------------------------------------------ derived state
    def cache(self) -> _DerivedCache:
        """Task-table aggregates (rebuilt after any mutation)."""
        if self._cache is None:
            self._cache = self._build_cache()
        return self._cache

    def _build_cache(self) -> _DerivedCache:
        n, nt = self.n_nodes, self.n_tasks_total
        n_tasks = np.bincount(self.t_node[:nt], minlength=n) if nt \
            else np.zeros(n, np.int64)
        first_user = np.full(n, -1, np.int64)
        mem_used = np.zeros(n, np.float64)
        if nt == 0:
            empty = np.empty(0, np.int64)
            return _DerivedCache(empty, empty, empty, empty, empty, empty,
                                 0, n_tasks, first_user, mem_used)
        node = self.t_node[:nt]
        order = np.argsort(node, kind="stable")
        nsort = node[order]
        boundary = np.empty(nt, bool)
        boundary[0] = True
        np.not_equal(nsort[1:], nsort[:-1], out=boundary[1:])
        starts = np.flatnonzero(boundary)
        occ_nodes = nsort[starts]
        counts = np.empty(len(starts), np.int64)
        counts[:-1] = starts[1:] - starts[:-1]
        counts[-1] = nt - starts[-1]
        pos = np.arange(nt, dtype=np.int64) - np.repeat(starts, counts)
        row = np.repeat(np.arange(len(occ_nodes), dtype=np.int64), counts)
        width = int(counts.max())
        first_user[occ_nodes] = self.t_user[:nt][order][starts]
        cache = _DerivedCache(order, occ_nodes, starts, counts, row, pos,
                              width, n_tasks, first_user, mem_used)
        mem_vals = self._prof_mem[self.t_prof[:nt]]
        mem_used[occ_nodes] = self._seg_sum_ordered(cache, mem_vals)
        return cache

    def _seg_sum_ordered(self, cache: _DerivedCache,
                         vals: np.ndarray) -> np.ndarray:
        """Per-node sum of per-task ``vals`` in task insertion order —
        bitwise-identical to the object path's sequential ``acc += v``
        (a padded column sweep; trailing ``+ 0.0`` keeps non-negative
        accumulators exact).  Returns one sum per ``cache.occ_nodes``."""
        padded = np.zeros((len(cache.occ_nodes), cache.width), np.float64)
        padded[cache.row, cache.pos] = vals[cache.order]
        acc = np.zeros(len(cache.occ_nodes), np.float64)
        for j in range(cache.width):
            acc += padded[:, j]
        return acc

    # ----------------------------------------------------------- queries
    def users_per_node(self) -> np.ndarray:
        """Distinct alive users per node (whole-node invariant sweep)."""
        nt = self.n_tasks_total
        out = np.zeros(self.n_nodes, np.int64)
        if nt:
            n_users = max(len(self.user_names), 1)
            pairs = np.unique(self.t_node[:nt] * n_users + self.t_user[:nt])
            np.add.at(out, pairs // n_users, 1)
        return out

    def task_indices_of_node(self, idx: int) -> np.ndarray:
        """Row indices of node ``idx``'s tasks, in insertion order."""
        return np.flatnonzero(self.t_node[: self.n_tasks_total] == idx)

    # ---------------------------------------------------------- snapshot
    def _duty_tables(self, t: float, mod: int, seeds: np.ndarray,
                     method: str) -> np.ndarray:
        """Per-task duty values at time ``t``: evaluate the *Python*
        profile curve once per unique ``(profile, seed mod m)`` pair and
        gather — bitwise-identical to calling it per task.  The unique
        pairs depend only on fleet state, so they are cached per
        ``version`` and only the (tiny) table is re-evaluated per ``t``."""
        nt = self.n_tasks_total
        entry = self._duty_keys.get(mod)
        if entry is None or entry[0] != self.version:
            keys = self.t_prof[:nt] * mod + seeds[self.t_node[:nt]]
            uniq, inv = np.unique(keys, return_inverse=True)
            pairs = [divmod(int(k), mod) for k in uniq.tolist()]
            entry = (self.version, pairs, inv)
            self._duty_keys[mod] = entry
        _, pairs, inv = entry
        profiles = self.profiles
        table = np.empty(len(pairs), np.float64)
        for i, (pid, s) in enumerate(pairs):
            table[i] = getattr(profiles[pid], method)(t, s)
        return table[inv]

    def _static_snapshot_cols(self, cache: _DerivedCache) -> tuple:
        """The t-independent snapshot columns (occupancy, memory, device
        counts), rebuilt only when the fleet mutates."""
        if self._static_cols is not None \
                and self._static_cols[0] == self.version:
            return self._static_cols
        n, nt = self.n_nodes, self.n_tasks_total
        gmem = np.zeros(n, np.float64)
        gused = np.zeros(n, np.int64)
        if nt:
            occ_nodes = cache.occ_nodes
            gmem[occ_nodes] = self._seg_sum_ordered(
                cache, self._prof_gpu_mem[self.t_prof[:nt]])
            ormask = np.bitwise_or.reduceat(
                self.t_gmask[:nt][cache.order], cache.starts)
            pop = np.zeros(len(occ_nodes), np.int64)
            for g in range(self.max_gpus):
                pop += (ormask >> g) & 1
            gused[occ_nodes] = pop
        self._static_cols = (
            self.version,
            np.minimum(self.cores_used, self.cores),
            np.minimum(cache.mem_used, self.mem_gb),
            gused,
            np.minimum(gmem, self.gpu_mem_total),
            (self.gpus > 0) & (gused > 0),      # busy-GPU-node mask
            np.maximum(gused, 1),               # gpu_load denominator
        )
        return self._static_cols

    def snapshot_columns(self, t: float) -> NodeColumns:
        """Whole-fleet :class:`NodeColumns` at sim time ``t`` in one
        vectorized pass (per-task duty via array-evaluated profile
        curves, segment-reduced per node in insertion order)."""
        n, nt = self.n_nodes, self.n_tasks_total
        cache = self.cache()
        (_, cores_used, mem_used, gused, gmem,
         gpu_busy, gpu_denom) = self._static_snapshot_cols(cache)
        load = np.zeros(n, np.float64)
        duty = np.zeros(n, np.float64)
        if nt:
            occ_nodes = cache.occ_nodes
            load[occ_nodes] = self._seg_sum_ordered(
                cache, self._duty_tables(t, 97, self.smod97, "cpu_load"))
            duty[occ_nodes] = self._seg_sum_ordered(
                cache, self._duty_tables(t, 89, self.smod89, "gpu_load"))
        gpu_load = np.where(
            gpu_busy, np.minimum(1.0, duty / gpu_denom), 0.0)
        return NodeColumns(
            hostnames=self.hostnames,
            cores_total=self.cores,
            cores_used=cores_used,
            load=load,
            mem_total_gb=self.mem_gb,
            mem_used_gb=mem_used,
            gpus_total=self.gpus,
            gpus_used=gused,
            gpu_load=gpu_load,
            gpu_mem_total_gb=self.gpu_mem_total,
            gpu_mem_used_gb=gmem,
            index=self.host_index,
        )


def gpu_task_capacity(caps: np.ndarray, gpt: int) -> np.ndarray:
    """Max tasks placeable per node when each task needs ``gpt``
    *distinct* GPUs and GPU ``i`` has ``caps[:, i]`` free slots.

    ``m`` tasks are feasible iff ``sum_i min(caps_i, m) >= m * gpt``
    (each GPU serves a task at most once, so at most ``min(caps_i, m)``
    times) — the Gale-Ryser-style bound the greedy least-occupied
    assignment achieves.  ``g(m) = sum_i min(caps_i, m) - m*gpt`` is
    concave with ``g(0) = 0``, so the answer is the floor of g's
    positive root; candidates are evaluated per linear segment.

    Args:
        caps: ``(nodes, G)`` int array of free slots per GPU.
        gpt: GPUs required per task (>= 1).

    Returns:
        int64 array of per-node task capacities.
    """
    n, G = caps.shape
    if gpt == 1:
        return caps.sum(axis=1)
    asc = np.sort(caps, axis=1)
    prefix = np.concatenate(
        [np.zeros((n, 1), np.int64), np.cumsum(asc, axis=1)], axis=1)
    best = np.zeros(n, np.int64)
    for j in range(G + 1):
        # segment where exactly (G - j) GPUs still grow with m:
        # g(m) = prefix[:, j] + m*(G - j) - m*gpt; crossing at slope < 0
        slope = (G - j) - gpt
        if slope >= 0:
            continue
        cand = prefix[:, j] // (-slope)
        feas = (np.minimum(asc, cand[:, None]).sum(axis=1)
                >= cand * gpt)
        best = np.maximum(best, np.where(feas, cand, 0))
    return best

"""Discrete-time cluster simulator: drives the scheduler and materializes
LLload :class:`ClusterSnapshot`s from running task profiles.

``snapshot()`` is columnar (DESIGN.md §10): per-task cpu/gpu duty is
evaluated through :meth:`FleetState.snapshot_columns` in one vectorized
pass and the per-node view comes back as a lazy
:class:`~repro.core.metrics.ColumnarNodeMap` — a ``NodeSnapshot`` is
only built for hosts a consumer actually touches, which is what makes
100k-node snapshots cheap.  Output is bitwise-identical to the object
path preserved in :mod:`repro.cluster.baseline` (golden + property
tested).
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.cluster.job import JobSpec
from repro.cluster.node import NodeSpec
from repro.cluster.scheduler import Scheduler
from repro.core.metrics import ClusterSnapshot, JobRecord


class ClusterSim:
    def __init__(self, nodes: List[NodeSpec], *, cluster: str = "txgreen",
                 partitions: Optional[dict] = None, seed: int = 0,
                 show_pending: bool = False):
        """``show_pending`` additionally surfaces queued (``PD``) jobs in
        snapshots — opt-in so existing consumers (and goldens) keep
        seeing only running jobs; the arrival-driven experiment
        scenarios enable it so queue-wait rules can observe the queue."""
        self.cluster = cluster
        self.sched = Scheduler(nodes, partitions)
        self.t = 0.0
        self.seed = seed
        self.show_pending = show_pending
        self.user_emails: Dict[str, str] = {}
        self._jobrec: Dict[int, JobRecord] = {}

    # ------------------------------------------------------------ control
    def submit(self, spec: JobSpec, *, now: Optional[float] = None) -> int:
        """Queue a job and return its id.  ``now`` overrides the recorded
        submit time (default: the current sim clock) so arrival-driven
        experiments can stamp a job with its nominal arrival time even
        when submissions are batched between steps."""
        self.user_emails.setdefault(spec.username,
                                    f"{spec.username}@ll.mit.edu")
        return self.sched.submit(spec, self.t if now is None else now).job_id

    def step(self, dt: float = 60.0):
        self.t += dt
        self.sched.tick(self.t)

    def run_until(self, t: float, dt: float = 60.0):
        while self.t < t:
            self.step(min(dt, t - self.t))

    def as_source(self, *, advance_s: float = 0.0,
                  name: Optional[str] = None,
                  interval_hint: Optional[float] = None):
        """This sim as a :class:`repro.monitor.source.MetricSource`.

        ``advance_s`` > 0 makes each poll advance simulated time, so a
        TelemetryBus watching the sim sees the cluster evolve."""
        from repro.monitor.source import SimSource

        return SimSource(self, advance_s=advance_s, name=name,
                         interval_hint=interval_hint)

    # ----------------------------------------------------------- snapshot
    def _job_record(self, job) -> JobRecord:
        """JobRecord for a running job, cached per job id — placement is
        final at dispatch, so the record never changes while the job runs
        (cancel+resubmit mints a new id)."""
        rec = self._jobrec.get(job.job_id)
        if rec is None:
            s = job.spec
            rec = JobRecord(
                job_id=job.job_id, username=s.username, name=s.name,
                nodes=list(job.hostnames), cores_per_node=s.cores_per_task,
                state="R", job_type=s.job_type,
                gpus_per_node=s.gpus_per_task, gpu_request=s.gpu_request,
                start_time=job.start_time or 0.0, partition=s.partition,
                mem_per_node_gb=s.profile.mem_gb,
                submit_time=job.submit_time or 0.0)
            self._jobrec[job.job_id] = rec
        return rec

    def _pending_record(self, job) -> JobRecord:
        """JobRecord for a queued job — built fresh each snapshot (no
        cache: the record changes shape when the job dispatches)."""
        s = job.spec
        return JobRecord(
            job_id=job.job_id, username=s.username, name=s.name,
            nodes=[], cores_per_node=s.cores_per_task, state="PD",
            job_type=s.job_type, gpus_per_node=s.gpus_per_task,
            gpu_request=s.gpu_request, start_time=0.0,
            partition=s.partition, mem_per_node_gb=s.profile.mem_gb,
            submit_time=job.submit_time or 0.0)

    def snapshot(self) -> ClusterSnapshot:
        cols = self.sched.fleet.snapshot_columns(self.t)
        jobs = [self._job_record(job) for job in self.sched.running]
        if self.show_pending:
            jobs += [self._pending_record(job)
                     for job in self.sched.pending]
        if len(self._jobrec) > 4 * max(len(jobs), 16):
            alive = {job.job_id for job in self.sched.running}
            self._jobrec = {j: r for j, r in self._jobrec.items()
                            if j in alive}
        return ClusterSnapshot(self.cluster, self.t, cols.as_map(), jobs,
                               dict(self.user_emails))

"""Discrete-time cluster simulator: drives the scheduler and materializes
LLload :class:`ClusterSnapshot`s from running task profiles."""
from __future__ import annotations

import math
import zlib
from typing import Dict, List, Optional

from repro.cluster.job import JobSpec
from repro.cluster.node import NodeSpec
from repro.cluster.scheduler import Scheduler
from repro.core.metrics import ClusterSnapshot, JobRecord, NodeSnapshot


class ClusterSim:
    def __init__(self, nodes: List[NodeSpec], *, cluster: str = "txgreen",
                 partitions: Optional[dict] = None, seed: int = 0):
        self.cluster = cluster
        self.sched = Scheduler(nodes, partitions)
        self.t = 0.0
        self.seed = seed
        self.user_emails: Dict[str, str] = {}

    # ------------------------------------------------------------ control
    def submit(self, spec: JobSpec, *, now: Optional[float] = None) -> int:
        """Queue a job and return its id.  ``now`` overrides the recorded
        submit time (default: the current sim clock) so arrival-driven
        experiments can stamp a job with its nominal arrival time even
        when submissions are batched between steps."""
        self.user_emails.setdefault(spec.username,
                                    f"{spec.username}@ll.mit.edu")
        return self.sched.submit(spec, self.t if now is None else now).job_id

    def step(self, dt: float = 60.0):
        self.t += dt
        self.sched.tick(self.t)

    def run_until(self, t: float, dt: float = 60.0):
        while self.t < t:
            self.step(min(dt, t - self.t))

    def as_source(self, *, advance_s: float = 0.0,
                  name: Optional[str] = None,
                  interval_hint: Optional[float] = None):
        """This sim as a :class:`repro.monitor.source.MetricSource`.

        ``advance_s`` > 0 makes each poll advance simulated time, so a
        TelemetryBus watching the sim sees the cluster evolve."""
        from repro.monitor.source import SimSource

        return SimSource(self, advance_s=advance_s, name=name,
                         interval_hint=interval_hint)

    # ----------------------------------------------------------- snapshot
    def snapshot(self) -> ClusterSnapshot:
        nodes: Dict[str, NodeSnapshot] = {}
        for host, ns in self.sched.nodes.items():
            spec = ns.spec
            load = 0.0
            gpu_duty = 0.0
            gpu_mem = 0.0
            gpus_used = set()
            # stable per-host jitter seed: str.__hash__ is randomized per
            # process (PYTHONHASHSEED), which made snapshots non-reproducible
            hseed = zlib.crc32(host.encode())
            for task in ns.tasks:
                load += task.profile.cpu_load(self.t, hseed % 97)
                for g in task.gpu_slots:
                    gpus_used.add(g)
                gpu_duty += task.profile.gpu_load(self.t, hseed % 89)
                gpu_mem += task.profile.gpu_mem_gb
            # duty cycle saturates at 1.0 per device (the overloading payoff:
            # several low-duty tasks sum toward full utilization)
            gpu_load = 0.0
            if spec.gpus > 0 and gpus_used:
                gpu_load = min(1.0, gpu_duty / max(len(gpus_used), 1))
            nodes[host] = NodeSnapshot(
                hostname=host,
                cores_total=spec.cores,
                cores_used=min(ns.cores_used, spec.cores),
                load=load,
                mem_total_gb=spec.mem_gb,
                mem_used_gb=min(ns.mem_used(), spec.mem_gb),
                gpus_total=spec.gpus,
                gpus_used=len(gpus_used),
                gpu_load=gpu_load,
                gpu_mem_total_gb=spec.gpus * spec.gpu_mem_gb,
                gpu_mem_used_gb=min(gpu_mem, spec.gpus * spec.gpu_mem_gb),
            )
        jobs = []
        for job in self.sched.running:
            s = job.spec
            jobs.append(JobRecord(
                job_id=job.job_id, username=s.username, name=s.name,
                nodes=list(job.hostnames), cores_per_node=s.cores_per_task,
                state="R", job_type=s.job_type,
                gpus_per_node=s.gpus_per_task, gpu_request=s.gpu_request,
                start_time=job.start_time or 0.0, partition=s.partition,
                mem_per_node_gb=s.profile.mem_gb))
        return ClusterSnapshot(self.cluster, self.t, nodes, jobs,
                               dict(self.user_emails))

"""Slurm-like scheduler with the LLSC whole-node (per-user) policy (paper §III).

Policies:
  * ``whole-node`` partitions — once any task of user U runs on a node, only
    U's tasks may be co-scheduled there until the node drains [paper refs
    16, 17].  This is what makes per-user attribution cheap for LLload.
  * ``shared`` partitions — multi-user nodes for debug / Jupyter jobs (the
    special partitions the paper deployed to fix whole-node fragmentation).
  * ``exclusive`` jobs — node must be empty and stays single-job.

GPU overloading (paper §V-B): ``JobSpec.tasks_per_gpu > 1`` lets the
scheduler round-robin multiple tasks of the *same user* onto one GPU — the
NPPN mechanism LLsub/LLMapReduce expose.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.cluster.job import Job, JobSpec, RunningTask
from repro.cluster.node import NodeSpec


@dataclasses.dataclass
class NodeState:
    spec: NodeSpec
    tasks: List[RunningTask] = dataclasses.field(default_factory=list)
    exclusive_job: Optional[int] = None

    @property
    def user(self) -> Optional[str]:
        return self.tasks[0].username if self.tasks else None

    @property
    def users(self) -> set:
        return {t.username for t in self.tasks}

    @property
    def cores_used(self) -> int:
        return sum(t.cores for t in self.tasks)

    def gpu_occupancy(self) -> Dict[int, int]:
        occ = {i: 0 for i in range(self.spec.gpus)}
        for t in self.tasks:
            for g in t.gpu_slots:
                occ[g] += 1
        return occ

    def mem_used(self) -> float:
        return sum(t.profile.mem_gb for t in self.tasks)


class Scheduler:
    def __init__(self, nodes: List[NodeSpec],
                 partitions: Optional[Dict[str, dict]] = None):
        """partitions: name -> {"hosts": [..], "policy": "whole-node"|"shared"}.
        Default: every node in a single whole-node "normal" partition."""
        self.nodes: Dict[str, NodeState] = {
            n.hostname: NodeState(n) for n in nodes}
        if partitions is None:
            partitions = {"normal": {"hosts": [n.hostname for n in nodes],
                                     "policy": "whole-node"}}
        self.partitions = partitions
        self.pending: List[Job] = []
        self.running: List[Job] = []
        self.completed: List[Job] = []
        self._next_id = 26140000

    # ------------------------------------------------------------- submit
    def submit(self, spec: JobSpec, now: float) -> Job:
        job = Job(self._next_id, spec, submit_time=now)
        self._next_id += 1
        self.pending.append(job)
        return job

    # ----------------------------------------------------------- dispatch
    def _node_fits(self, ns: NodeState, job: Job, tasks: int) -> int:
        """How many tasks of `job` fit on node `ns` right now."""
        spec, jspec = ns.spec, job.spec
        part = self.partitions.get(jspec.partition)
        if part is None or ns.spec.hostname not in part["hosts"]:
            return 0
        if ns.exclusive_job is not None:
            return 0
        if jspec.exclusive and ns.tasks:
            return 0
        policy = part.get("policy", "whole-node")
        if policy == "whole-node" and ns.tasks and ns.user != jspec.username:
            return 0  # per-user whole-node isolation
        free_cores = spec.cores - ns.cores_used
        fit = free_cores // max(jspec.cores_per_task, 1)
        free_mem = spec.mem_gb - ns.mem_used()
        if jspec.profile.mem_gb > 0:
            fit = min(fit, int(free_mem // jspec.profile.mem_gb))
        if jspec.gpus_per_task > 0:
            occ = ns.gpu_occupancy()
            slots = sum(max(0, jspec.tasks_per_gpu - c) for c in occ.values())
            fit = min(fit, slots // jspec.gpus_per_task)
        return max(0, min(fit, tasks))

    def _place(self, ns: NodeState, job: Job, count: int):
        jspec = job.spec
        for _ in range(count):
            gpu_slots = ()
            if jspec.gpus_per_task > 0:
                occ = ns.gpu_occupancy()
                # round-robin: least-occupied GPUs first (paper's overloading)
                order = sorted(occ, key=lambda g: occ[g])
                chosen = [g for g in order
                          if occ[g] < jspec.tasks_per_gpu][: jspec.gpus_per_task]
                gpu_slots = tuple(chosen)
            ns.tasks.append(RunningTask(
                job.job_id, jspec.username, ns.spec.hostname, jspec.profile,
                jspec.cores_per_task, gpu_slots))
        if jspec.exclusive:
            ns.exclusive_job = job.job_id
        if ns.spec.hostname not in job.hostnames:
            job.hostnames.append(ns.spec.hostname)

    def _try_dispatch(self, job: Job, now: float) -> bool:
        remaining = job.spec.n_tasks
        plan = []
        # Prefer nodes this user already holds (packs whole nodes densely).
        def keyfn(ns):
            return (0 if ns.user == job.spec.username and ns.tasks else
                    (1 if not ns.tasks else 2), ns.spec.hostname)
        for ns in sorted(self.nodes.values(), key=keyfn):
            if remaining <= 0:
                break
            fit = self._node_fits(ns, job, remaining)
            if fit > 0:
                plan.append((ns, fit))
                remaining -= fit
        if remaining > 0:
            return False
        for ns, count in plan:
            self._place(ns, job, count)
        job.state = "R"
        job.start_time = now
        self.running.append(job)
        return True

    # ------------------------------------------------------------- cancel
    def cancel(self, job_id: int) -> Optional[Job]:
        """Cancel a pending or running job (state ``CA``), freeing any
        node slots it holds.  Returns the job, or ``None`` if ``job_id``
        is not pending/running (already completed, or unknown).

        This is the resubmission primitive the §V-B overloading loop
        uses: the experiment runner cancels a user's jobs and resubmits
        their specs at the controller's next NPPN level — work done so
        far is lost, exactly like a real re-submission.
        """
        for i, job in enumerate(self.pending):
            if job.job_id == job_id:
                job.state = "CA"
                return self.pending.pop(i)
        for i, job in enumerate(self.running):
            if job.job_id == job_id:
                job.state = "CA"
                self.running.pop(i)
                for ns in self.nodes.values():
                    ns.tasks = [t for t in ns.tasks if t.job_id != job_id]
                    if ns.exclusive_job == job_id:
                        ns.exclusive_job = None
                return job
        return None

    # ---------------------------------------------------------------- tick
    def tick(self, now: float):
        # completions
        still = []
        for job in self.running:
            if job.start_time is not None and \
                    now - job.start_time >= job.spec.duration_s:
                job.state = "CG"
                job.end_time = now
                for ns in self.nodes.values():
                    ns.tasks = [t for t in ns.tasks if t.job_id != job.job_id]
                    if ns.exclusive_job == job.job_id:
                        ns.exclusive_job = None
                self.completed.append(job)
            else:
                still.append(job)
        self.running = still
        # dispatch FIFO
        still_pending = []
        for job in self.pending:
            if not self._try_dispatch(job, now):
                still_pending.append(job)
        self.pending = still_pending

    # ---------------------------------------------------------- invariants
    def check_whole_node_invariant(self) -> List[str]:
        """Returns violations: whole-node partition nodes with >1 user."""
        bad = []
        shared_hosts = set()
        for part in self.partitions.values():
            if part.get("policy") == "shared":
                shared_hosts.update(part["hosts"])
        for host, ns in self.nodes.items():
            if host in shared_hosts:
                continue
            if len(ns.users) > 1:
                bad.append(host)
        return bad

"""Slurm-like scheduler with the LLSC whole-node (per-user) policy (paper §III).

Policies:
  * ``whole-node`` partitions — once any task of user U runs on a node, only
    U's tasks may be co-scheduled there until the node drains [paper refs
    16, 17].  This is what makes per-user attribution cheap for LLload.
  * ``shared`` partitions — multi-user nodes for debug / Jupyter jobs (the
    special partitions the paper deployed to fix whole-node fragmentation).
  * ``exclusive`` jobs — node must be empty and stays single-job.

GPU overloading (paper §V-B): ``JobSpec.tasks_per_gpu > 1`` lets the
scheduler round-robin multiple tasks of the *same user* onto one GPU — the
NPPN mechanism LLsub/LLMapReduce expose.  A task with ``gpus_per_task > 1``
needs that many *distinct* devices, each under the ``tasks_per_gpu`` cap.

Implementation (DESIGN.md §10): all node/task state lives in a columnar
:class:`~repro.cluster.fleet.FleetState`; fit checks, dispatch ordering,
completion and cancel are whole-fleet array expressions, which is what
lets experiment campaigns sweep LLSC-scale (100k-node) fleets.  The
per-node object API (``sched.nodes[host].tasks`` etc.) survives as lazy
:class:`NodeView`s over the arrays; the original object implementation
lives on in :mod:`repro.cluster.baseline` as the equivalence oracle.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.cluster.baseline import NodeState  # noqa: F401  (compat re-export)
from repro.cluster.baseline import gpu_fit_distinct
from repro.cluster.fleet import FleetState, gpu_task_capacity
from repro.cluster.job import Job, JobSpec, RunningTask
from repro.cluster.node import NodeSpec

#: Below this many nodes, dispatch walks candidate nodes in preference
#: order with early exit (the object path's algorithm over the columnar
#: arrays) instead of evaluating whole-fleet fit expressions — the ~35
#: fixed numpy dispatches per tick cost more than a short Python scan
#: until fleets get large (BENCH_sim.json pins the crossover).
SMALL_FLEET_MAX_NODES = 1024


def _mask_bits(mask: int) -> tuple:
    """Set-bit indices of a GPU bitmask, ascending."""
    out = []
    g = 0
    while mask:
        if mask & 1:
            out.append(g)
        mask >>= 1
        g += 1
    return tuple(out)


class NodeView:
    """``NodeState``-shaped read view over one :class:`FleetState` row.

    Consumers that still think in per-node objects (tests, debugging,
    the shared-node insight paths) read through this; the task list is
    reconstructed from the columnar task table on demand and cached
    until the fleet mutates.  ``gpu_slots`` come back in ascending
    device order (the bitmask drops pick order; every consumer treats
    the tuple as a set).
    """

    __slots__ = ("_fleet", "_idx", "_version", "_tasks")

    def __init__(self, fleet: FleetState, idx: int):
        self._fleet = fleet
        self._idx = idx
        self._version = -1
        self._tasks: List[RunningTask] = []

    @property
    def spec(self) -> NodeSpec:
        return self._fleet.specs[self._idx]

    @property
    def tasks(self) -> List[RunningTask]:
        f = self._fleet
        if self._version != f.version:
            host = f.hostnames[self._idx]
            self._tasks = [
                RunningTask(int(f.t_job[r]), f.user_names[int(f.t_user[r])],
                            host, f.profiles[int(f.t_prof[r])],
                            int(f.t_cores[r]), _mask_bits(int(f.t_gmask[r])))
                for r in f.task_indices_of_node(self._idx).tolist()]
            self._version = f.version
        return self._tasks

    @property
    def exclusive_job(self) -> Optional[int]:
        j = int(self._fleet.exclusive_job[self._idx])
        return None if j < 0 else j

    @property
    def user(self) -> Optional[str]:
        tasks = self.tasks
        return tasks[0].username if tasks else None

    @property
    def users(self) -> set:
        return {t.username for t in self.tasks}

    @property
    def cores_used(self) -> int:
        return int(self._fleet.cores_used[self._idx])

    def mem_used(self) -> float:
        total = 0.0
        for t in self.tasks:
            total += t.profile.mem_gb
        return total

    def gpu_occupancy(self) -> Dict[int, int]:
        occ = self._fleet.occ[self._idx]
        return {g: int(occ[g]) for g in range(self.spec.gpus)}


class FleetNodeMap:
    """Lazy ``hostname -> NodeView`` mapping (the ``Scheduler.nodes``
    dict shape, without 100k eager per-node objects)."""

    def __init__(self, fleet: FleetState):
        self._fleet = fleet
        self._views: Dict[str, NodeView] = {}

    def __getitem__(self, host: str) -> NodeView:
        view = self._views.get(host)
        if view is None:
            view = NodeView(self._fleet, self._fleet.host_index[host])
            self._views[host] = view
        return view

    def get(self, host: str, default=None):
        try:
            return self[host]
        except KeyError:
            return default

    def __contains__(self, host) -> bool:
        return host in self._fleet.host_index

    def __iter__(self) -> Iterator[str]:
        return iter(self._fleet.hostnames)

    def __len__(self) -> int:
        return self._fleet.n_nodes

    def __bool__(self) -> bool:
        return self._fleet.n_nodes > 0

    def keys(self):
        return list(self._fleet.hostnames)

    def values(self) -> List[NodeView]:
        return [self[h] for h in self._fleet.hostnames]

    def items(self):
        return [(h, self[h]) for h in self._fleet.hostnames]


class Scheduler:
    def __init__(self, nodes: List[NodeSpec],
                 partitions: Optional[Dict[str, dict]] = None):
        """partitions: name -> {"hosts": [..], "policy": "whole-node"|"shared"}.
        Default: every node in a single whole-node "normal" partition."""
        if partitions is None:
            partitions = {"normal": {"hosts": [n.hostname for n in nodes],
                                     "policy": "whole-node"}}
        self.partitions = partitions
        self.fleet = FleetState(nodes, partitions)
        self.nodes = FleetNodeMap(self.fleet)
        self.pending: List[Job] = []
        self.running: List[Job] = []
        self.completed: List[Job] = []
        self._next_id = 26140000
        # static per-partition candidate node lists in hostname order for
        # the small-fleet dispatch scan; the GPU variant drops nodes that
        # can never fit a GPU task (zero-fit nodes never enter a plan, so
        # skipping them preserves the dispatch order exactly)
        f = self.fleet
        rank = sorted(range(f.n_nodes), key=f.hostnames.__getitem__)
        self._part_rank: Dict[str, List[int]] = {}
        self._part_rank_gpu: Dict[str, List[int]] = {}
        for name in partitions:
            mask = f.part_mask[name]
            lst = [i for i in rank if mask[i]]
            self._part_rank[name] = lst
            self._part_rank_gpu[name] = [i for i in lst if f.gpus[i] > 0]

    # ------------------------------------------------------------- submit
    def submit(self, spec: JobSpec, now: float) -> Job:
        job = Job(self._next_id, spec, submit_time=now)
        self._next_id += 1
        self.pending.append(job)
        return job

    # ----------------------------------------------------------- dispatch
    def _fits(self, jspec: JobSpec) -> np.ndarray:
        """Per-node task fit for a job, whole fleet at once (the array
        form of the object path's per-node ``_node_fits`` loop)."""
        f = self.fleet
        part = self.partitions.get(jspec.partition)
        mask = f.part_mask.get(jspec.partition)
        if part is None or mask is None:
            return np.zeros(f.n_nodes, np.int64)
        cache = f.cache()
        has = cache.n_tasks > 0
        elig = mask & (f.exclusive_job < 0)
        if jspec.exclusive:
            elig &= ~has
        if part.get("policy", "whole-node") == "whole-node":
            uid = f.user_id(jspec.username)
            elig &= ~(has & (cache.first_user != uid))
        fit = (f.cores - f.cores_used) // max(jspec.cores_per_task, 1)
        m = jspec.profile.mem_gb
        if m > 0:
            fit = np.minimum(fit, np.floor_divide(
                f.mem_gb - cache.mem_used, m).astype(np.int64))
        if jspec.gpus_per_task > 0:
            caps = np.clip(jspec.tasks_per_gpu - f.occ, 0, None)
            # columns past a node's real device count hold no capacity
            caps[np.arange(f.occ.shape[1])[None, :] >= f.gpus[:, None]] = 0
            fit = np.minimum(fit, gpu_task_capacity(
                caps, jspec.gpus_per_task))
        return np.where(elig, np.maximum(fit, 0), 0)

    def _node_fit_py(self, idx: int, jspec: JobSpec, mask: np.ndarray,
                     whole: bool, uid: int, remaining: int) -> int:
        """Single-node task fit, mirroring the object path's
        ``_node_fits`` check for check (the small-fleet dispatch scan
        calls this only until the job's tasks are covered)."""
        f = self.fleet
        if not mask[idx] or f.exclusive_job[idx] >= 0:
            return 0
        n_on = int(f.n_tasks_node[idx])
        if jspec.exclusive and n_on:
            return 0
        if whole and n_on and int(f.first_user_node[idx]) != uid:
            return 0
        fit = (int(f.cores[idx]) - int(f.cores_used[idx])) \
            // max(jspec.cores_per_task, 1)
        m = jspec.profile.mem_gb
        if m > 0:
            mem_used = 0.0
            if n_on:
                rows = np.flatnonzero(
                    f.t_node[: f.n_tasks_total] == idx)
                # sequential adds in insertion order — same float sum the
                # object path's mem_used() walk produces
                for v in f._prof_mem[f.t_prof[rows]].tolist():
                    mem_used += v
            fit = min(fit, int((float(f.mem_gb[idx]) - mem_used) // m))
        if jspec.gpus_per_task > 0:
            occ_row = f.occ[idx].tolist()
            occ = {g: occ_row[g] for g in range(int(f.gpus[idx]))}
            fit = gpu_fit_distinct(occ, jspec.tasks_per_gpu,
                                   jspec.gpus_per_task, max(fit, 0))
        return max(0, min(fit, remaining))

    def _dispatch_small(self, job: Job, now: float) -> bool:
        """Early-exit dispatch for small fleets: walk candidates in the
        same (user-held, empty, other) × hostname preference order the
        array path sorts by, stopping as soon as the job is covered.
        Produces the identical placement plan — zero-fit nodes never
        enter a plan, so skipping whole categories of them is safe."""
        f = self.fleet
        jspec = job.spec
        plan: List[tuple] = []
        if jspec.n_tasks > 0:
            part = self.partitions.get(jspec.partition)
            mask = f.part_mask.get(jspec.partition)
            if part is None or mask is None:
                return False
            whole = part.get("policy", "whole-node") == "whole-node"
            uid = f.user_id(jspec.username)
            remaining = jspec.n_tasks
            held = np.flatnonzero((f.n_tasks_node > 0)
                                  & (f.first_user_node == uid))
            if len(held) > 1:
                held = held[np.argsort(f.hostrank[held])]
            held_list = held.tolist()
            for idx in held_list:                 # cat 0: user-held nodes
                fit = self._node_fit_py(idx, jspec, mask, whole, uid,
                                        remaining)
                if fit > 0:
                    plan.append((idx, fit))
                    remaining -= fit
                    if remaining <= 0:
                        break
            cand = (self._part_rank_gpu if jspec.gpus_per_task > 0
                    else self._part_rank).get(jspec.partition, ())
            ntn = f.n_tasks_node_tolist()
            if remaining > 0:
                for idx in cand:                  # cat 1: empty nodes
                    if ntn[idx] == 0:
                        fit = self._node_fit_py(idx, jspec, mask, whole,
                                                uid, remaining)
                        if fit > 0:
                            plan.append((idx, fit))
                            remaining -= fit
                            if remaining <= 0:
                                break
            if remaining > 0:
                held_set = set(held_list)
                for idx in cand:                  # cat 2: other users'
                    if ntn[idx] > 0 and idx not in held_set:
                        fit = self._node_fit_py(idx, jspec, mask, whole,
                                                uid, remaining)
                        if fit > 0:
                            plan.append((idx, fit))
                            remaining -= fit
                            if remaining <= 0:
                                break
            if remaining > 0:
                return False
        for idx, count in plan:
            f.place(idx, job, count)
        job.state = "R"
        job.start_time = now
        self.running.append(job)
        return True

    def _try_dispatch(self, job: Job, now: float) -> bool:
        f = self.fleet
        if f.n_nodes <= SMALL_FLEET_MAX_NODES:
            return self._dispatch_small(job, now)
        jspec = job.spec
        if jspec.n_tasks > 0:
            fits = self._fits(jspec)
            idxs = np.flatnonzero(fits)
            if int(fits[idxs].sum()) < jspec.n_tasks:
                return False
            # Prefer nodes this user already holds (packs whole nodes
            # densely), then empty nodes, then other shared nodes; ties by
            # hostname — same order the object path got from its keyfn sort.
            cache = f.cache()
            uid = f.user_id(jspec.username)
            has = cache.n_tasks[idxs] > 0
            cat = np.where(has & (cache.first_user[idxs] == uid), 0,
                           np.where(~has, 1, 2))
            order = np.argsort(cat * f.n_nodes + f.hostrank[idxs])
            plan = idxs[order]
            csum = np.cumsum(fits[plan])
            k = int(np.searchsorted(csum, jspec.n_tasks, side="left"))
            counts = fits[plan[: k + 1]].copy()
            counts[k] = jspec.n_tasks - (int(csum[k - 1]) if k else 0)
            for idx, count in zip(plan[: k + 1].tolist(), counts.tolist()):
                f.place(idx, job, count)
        job.state = "R"
        job.start_time = now
        self.running.append(job)
        return True

    @staticmethod
    def _fit_key(jspec: JobSpec) -> tuple:
        """Everything `_fits` depends on besides fleet state — two jobs
        with the same key see identical per-node fits."""
        return (jspec.username, jspec.partition, jspec.cores_per_task,
                jspec.profile.mem_gb, jspec.gpus_per_task,
                jspec.tasks_per_gpu, jspec.exclusive)

    # ------------------------------------------------------------- cancel
    def cancel(self, job_id: int) -> Optional[Job]:
        """Cancel a pending or running job (state ``CA``), freeing any
        node slots it holds.  Returns the job, or ``None`` if ``job_id``
        is not pending/running (already completed, or unknown).

        This is the resubmission primitive the §V-B overloading loop
        uses: the experiment runner cancels a user's jobs and resubmits
        their specs at the controller's next NPPN level — work done so
        far is lost, exactly like a real re-submission.
        """
        for i, job in enumerate(self.pending):
            if job.job_id == job_id:
                job.state = "CA"
                return self.pending.pop(i)
        for i, job in enumerate(self.running):
            if job.job_id == job_id:
                job.state = "CA"
                self.running.pop(i)
                # free only the hosts the job ran on, not the whole fleet
                self.fleet.free_jobs((job_id,), job.hostnames)
                return job
        return None

    # ---------------------------------------------------------------- tick
    def tick(self, now: float):
        # completions: one boolean-mask compaction for every job that
        # finished this tick, touching only their recorded hostnames
        done = [job for job in self.running
                if job.start_time is not None
                and now - job.start_time >= job.spec.duration_s]
        if done:
            done_ids = set()
            hosts: List[str] = []
            for job in done:
                job.state = "CG"
                job.end_time = now
                done_ids.add(job.job_id)
                hosts.extend(job.hostnames)
            self.running = [j for j in self.running
                            if j.job_id not in done_ids]
            self.fleet.free_jobs(done_ids, hosts)
            self.completed.extend(done)
        # dispatch FIFO; a failed dispatch leaves state untouched, so any
        # later job with the same fit key and at least as many tasks must
        # fail too — skip it (cleared whenever a dispatch changes state)
        still_pending: List[Job] = []
        failed_at: Dict[tuple, int] = {}
        for job in self.pending:
            key = self._fit_key(job.spec)
            bar = failed_at.get(key)
            if bar is not None and job.spec.n_tasks >= bar:
                still_pending.append(job)
                continue
            if self._try_dispatch(job, now):
                failed_at.clear()
            else:
                failed_at[key] = job.spec.n_tasks if bar is None \
                    else min(bar, job.spec.n_tasks)
                still_pending.append(job)
        self.pending = still_pending

    # ---------------------------------------------------------- invariants
    def check_whole_node_invariant(self) -> List[str]:
        """Returns violations: whole-node partition nodes with >1 user."""
        f = self.fleet
        bad = (f.users_per_node() > 1) & ~f.shared_mask
        return [f.hostnames[i] for i in np.flatnonzero(bad).tolist()]

"""Job model: resource request + behavioural profile.

A job is ``n_tasks`` identical tasks.  The *profile* drives what the
monitoring sees: how many threads each task spins, its CPU duty cycle, its
GPU duty cycle and GPU memory.  The pathological profiles reproduce the
paper's case studies (Figs 7, 8, 10, 11).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class TaskProfile:
    threads: int = 1              # threads each task spawns
    cpu_activity: float = 1.0     # duty cycle of each thread (0..1)
    mem_gb: float = 4.0
    gpu_frac: float = 0.0         # GPU duty cycle contributed by one task
    gpu_mem_gb: float = 0.0
    jitter: float = 0.02          # deterministic sinusoidal load jitter

    def cpu_load(self, t: float, seed: int) -> float:
        base = self.threads * self.cpu_activity
        return max(0.0, base * (1.0 + self.jitter
                                * math.sin(0.001 * t + seed * 2.39996)))

    def gpu_load(self, t: float, seed: int) -> float:
        return max(0.0, self.gpu_frac * (1.0 + self.jitter
                                         * math.sin(0.0013 * t + seed * 1.7)))


@dataclasses.dataclass(frozen=True)
class JobSpec:
    username: str
    name: str
    n_tasks: int
    cores_per_task: int
    gpus_per_task: int = 0
    duration_s: float = 3600.0
    profile: TaskProfile = TaskProfile()
    partition: str = "normal"
    job_type: str = "batch"       # batch | jupyter | debug
    exclusive: bool = False
    # NPPN-style GPU overloading: tasks per GPU (1 = no oversubscription).
    tasks_per_gpu: int = 1
    gpu_request: str = ""


@dataclasses.dataclass
class RunningTask:
    job_id: int
    username: str
    hostname: str
    profile: TaskProfile
    cores: int
    gpu_slots: tuple = ()         # indices of GPUs this task occupies


@dataclasses.dataclass
class Job:
    job_id: int
    spec: JobSpec
    submit_time: float
    start_time: Optional[float] = None
    end_time: Optional[float] = None
    state: str = "PD"             # PD | R | CG | F
    hostnames: list = dataclasses.field(default_factory=list)

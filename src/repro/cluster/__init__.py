from repro.cluster.baseline import ObjectClusterSim, ObjectScheduler
from repro.cluster.fleet import FleetState, gpu_task_capacity
from repro.cluster.job import Job, JobSpec, TaskProfile
from repro.cluster.node import NodeSpec, make_nodes
from repro.cluster.scheduler import Scheduler
from repro.cluster.simulator import ClusterSim
from repro.cluster.workloads import make_llsc_sim, paper_scenario

__all__ = ["Job", "JobSpec", "TaskProfile", "NodeSpec", "make_nodes",
           "Scheduler", "ClusterSim", "FleetState", "gpu_task_capacity",
           "ObjectScheduler", "ObjectClusterSim",
           "make_llsc_sim", "paper_scenario"]

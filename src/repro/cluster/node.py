"""Compute-node model for the cluster simulator."""
from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    hostname: str
    cores: int = 48
    mem_gb: float = 192.0
    gpus: int = 0
    gpu_mem_gb: float = 0.0     # per GPU


def make_nodes(prefix: str, count: int, *, cores=48, mem_gb=192.0, gpus=0,
               gpu_mem_gb=0.0, racks=20) -> List[NodeSpec]:
    """LLSC-style hostnames: <prefix>-<rack>-<chassis>-<slot>."""
    nodes = []
    for i in range(count):
        rack = i // (racks) + 1
        chassis = (i % racks) // 4 + 1
        slot = i % 4 + 1
        nodes.append(NodeSpec(f"{prefix}-{rack}-{chassis}-{slot}", cores,
                              mem_gb, gpus, gpu_mem_gb))
    return nodes

"""Quickstart: LLload against a simulated LLSC cluster (no JAX needed).

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's CLI views (Figs 2-5), runs the advisor on the
pathological users, and prints a weekly-style report.
"""
import random

from repro.cluster.workloads import make_llsc_sim, paper_scenario
from repro.core.advisor import characterize_all
from repro.core.analysis import weekly_analysis
from repro.core.formatting import (format_all_view, format_top,
                                   format_user_view)
from repro.core.llload import LLload
from repro.core.metrics import rows_from_tsv
from repro.core.report import format_weekly_report


def main():
    sim = make_llsc_sim()
    paper_scenario(sim, random.Random(0))
    sim.run_until(3600.0)
    snap = sim.snapshot()
    ll = LLload(snap, privileged_users={"admin"})

    print("=" * 70)
    print("$ LLload            (as user va67890)          [paper Fig 2]")
    print("=" * 70)
    print(format_user_view(snap.cluster, ll.user_view("va67890")))

    print()
    print("=" * 70)
    print("$ LLload -g                                     [paper Fig 3]")
    print("=" * 70)
    print(format_user_view(snap.cluster, ll.user_view("va67890"), gpu=True))

    print()
    print("=" * 70)
    print("$ LLload --all -g   (privileged)                [paper Fig 4]")
    print("=" * 70)
    print(format_all_view(ll.all_view("admin"), gpu=True)[:2000])

    print()
    print("=" * 70)
    print("$ LLload -t 5                                   [paper Fig 5]")
    print("=" * 70)
    print(format_top(ll.top_loaded(5), 5))

    print()
    print("=" * 70)
    print("Advisor (usage characterization, paper §V-B)")
    print("=" * 70)
    for a in characterize_all(snap):
        print(f"[{a.kind:>14}] {a.username}: {a.message}")

    print()
    print("=" * 70)
    print("Weekly-style report from this snapshot          [paper Fig 6]")
    print("=" * 70)
    rows = rows_from_tsv(snap.to_tsv())
    print(format_weekly_report(weekly_analysis(rows, sim.user_emails)))


if __name__ == "__main__":
    main()

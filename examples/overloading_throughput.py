"""The paper's §V-B claim, reproduced end to end: device overloading (the
NPPN mechanism) improves aggregate throughput for low-utilization jobs.

    PYTHONPATH=src python examples/overloading_throughput.py

Two views:
  1. campaign: the declarative experiment harness (repro.experiments,
     DESIGN.md §9) sweeps the fixed NPPN ladder AND the closed loop
     (InsightEngine -> OverloadController.consume -> resubmission) over
     the simulated LLSC fleet — the same sweep `LLload --experiment
     examples/overload_campaign.toml` runs, here driven from Python,
  2. measured: a real JAX decode workload at 1/2/4/8 concurrent streams
     next to the analytic packing model.
"""
import os

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.overload import packed_throughput_model
from repro.experiments import load_campaign, render_result, run_campaign
from repro.models import init_params
from repro.serve.engine import EngineConfig, Request, ServeEngine

CAMPAIGN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "overload_campaign.toml")


def campaign_view():
    print("=" * 70)
    print("1) Campaign: fixed NPPN ladder vs the closed loop (8-node fleet)")
    print("=" * 70)
    campaign = load_campaign(CAMPAIGN)
    result = run_campaign(campaign, cells="low_duty/8g/*")
    print(render_result(result,
                        columns="cell,mode,nppn,tasks_done,throughput,"
                                "speedup,gpu_duty,queue_wait_s"), end="")
    controller = result.cell_row("low_duty/8g/controller")
    print(f"-> the controller converged on NPPN={controller['nppn']} and "
          f"delivered {controller['speedup']:.2f}x the fixed NPPN=1 "
          "throughput (paper Figs 5-7): freed capacity, shorter queue")


def measured_view():
    print()
    print("=" * 70)
    print("2) Measured: decode throughput vs concurrent streams")
    print("=" * 70)
    cfg = reduced_config("llsc-100m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    base = None
    print(f"{'streams':>8} {'tok/s':>9} {'speedup':>8}   model-predicted")
    for slots in (1, 2, 4, 8):
        eng = ServeEngine(cfg, params, EngineConfig(
            slots=slots, max_seq_len=64, monitor=False))
        for i in range(16):
            eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 8)
                               .astype(np.int32), max_new_tokens=8))
        stats = eng.run()
        tps = stats["tokens_per_s"]
        base = base or tps
        pred = (packed_throughput_model(0.35, slots)
                / packed_throughput_model(0.35, 1))
        print(f"{slots:>8} {tps:>9.1f} {tps / base:>8.2f}   {pred:.2f}x")


if __name__ == "__main__":
    campaign_view()
    measured_view()

"""The paper's §V-B claim, reproduced end to end: device overloading (the
NPPN mechanism) improves aggregate throughput for low-utilization jobs.

    PYTHONPATH=src python examples/overloading_throughput.py

Three views:
  1. scheduler-level: tasks_per_gpu sweep on the simulated cluster shows
     node-count shrinking while aggregate GPU duty rises (Figs 8->9),
  2. measured: a real JAX decode workload at 1/2/4/8 concurrent streams,
  3. closed loop: the OverloadController stepping NPPN from live duty.
"""
import jax
import numpy as np

from repro.cluster.workloads import make_llsc_sim, overloaded_gpu_job
from repro.configs import reduced_config
from repro.core.overload import (DeviceObservation, OverloadController,
                                 packed_throughput_model)
from repro.models import init_params
from repro.serve.engine import EngineConfig, Request, ServeEngine


def scheduler_view():
    print("=" * 70)
    print("1) Scheduler view: same 8 low-duty tasks, rising NPPN")
    print("=" * 70)
    print(f"{'NPPN':>5} {'nodes used':>11} {'mean GPU duty':>14}")
    for nppn in (1, 2, 4, 8):
        sim = make_llsc_sim()
        sim.submit(overloaded_gpu_job("u", tasks=8, tasks_per_gpu=nppn))
        sim.run_until(600.0)
        snap = sim.snapshot()
        hosts = snap.nodes_by_user().get("u", [])
        duties = [snap.nodes[h].gpu_load for h in hosts
                  if snap.nodes[h].gpus_total]
        print(f"{nppn:>5} {len(hosts):>11} {np.mean(duties):>14.2f}")
    print("-> fewer nodes, higher duty: freed nodes serve other users "
          "(paper Fig 9)")


def measured_view():
    print()
    print("=" * 70)
    print("2) Measured: decode throughput vs concurrent streams")
    print("=" * 70)
    cfg = reduced_config("llsc-100m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    base = None
    print(f"{'streams':>8} {'tok/s':>9} {'speedup':>8}   model-predicted")
    for slots in (1, 2, 4, 8):
        eng = ServeEngine(cfg, params, EngineConfig(
            slots=slots, max_seq_len=64, monitor=False))
        for i in range(16):
            eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 8)
                               .astype(np.int32), max_new_tokens=8))
        stats = eng.run()
        tps = stats["tokens_per_s"]
        base = base or tps
        pred = (packed_throughput_model(0.35, slots)
                / packed_throughput_model(0.35, 1))
        print(f"{slots:>8} {tps:>9.1f} {tps / base:>8.2f}   {pred:.2f}x")


def closed_loop_view():
    print()
    print("=" * 70)
    print("3) Closed loop: OverloadController steps NPPN 1 -> 2 -> 4")
    print("=" * 70)
    ctl = OverloadController()
    nppn, per_task = 1, 0.22
    for it in range(5):
        duty = min(1.0, per_task * nppn)
        for _ in range(4):
            ctl.observe(DeviceObservation(duty_cycle=duty, mem_used_gb=2.0,
                                          mem_total_gb=32.0))
        d = ctl.decide(nppn)
        print(f"  iter {it}: duty={duty:.2f} NPPN {nppn} -> {d.nppn} "
              f"({d.reason})")
        nppn = d.nppn


if __name__ == "__main__":
    scheduler_view()
    measured_view()
    closed_loop_view()

"""Full paper pipeline (Fig 1): simulate a week, archive 15-min snapshots,
run the weekly analysis, and draft the notification emails.

    PYTHONPATH=src python examples/monitor_cluster.py [--days 2]
"""
import argparse
import random
import tempfile

from repro.cluster.workloads import make_llsc_sim, paper_scenario
from repro.core.advisor import characterize_user
from repro.core.analysis import weekly_analysis
from repro.core.archive import PeriodicArchiver, SnapshotArchive
from repro.core.collector import SimCollector
from repro.core.report import format_weekly_report, notification_email


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=int, default=2)
    ap.add_argument("--archive-dir", default=None)
    args = ap.parse_args()

    sim = make_llsc_sim()
    paper_scenario(sim, random.Random(0))
    root = args.archive_dir or tempfile.mkdtemp(prefix="llload-archive-")
    archive = SnapshotArchive(root, cluster="txgreen")
    archiver = PeriodicArchiver(archive, SimCollector(sim))

    print(f"simulating {args.days} day(s), archiving to {root} ...")
    captured = 0
    for _ in range(args.days * 24 * 4):
        sim.step(900.0)                       # 15 minutes
        captured += archiver.maybe_capture(sim.t)
    print(f"captured {captured} snapshots "
          f"({len(archive.files())} daily TSV files)")

    rows = archive.rows()
    rep = weekly_analysis(rows, emails=sim.user_emails)
    print()
    print(format_weekly_report(rep))

    print()
    print("=" * 70)
    print("Notification emails (paper §V-B, drafted, not sent)")
    print("=" * 70)
    snap = sim.snapshot()
    for cat in ("low_gpu", "high_cpu"):
        rows_cat = getattr(rep, cat)
        if not rows_cat:
            continue
        top = rows_cat[0]
        advice = characterize_user(snap, top.username)
        advice_text = "\n".join(f"  - {a.message}" for a in advice) or None
        mail = notification_email(top, cat, advice_text)
        print(f"\n--- To: {mail.to}\n--- Subject: {mail.subject}")
        print(mail.body[:600])


if __name__ == "__main__":
    main()

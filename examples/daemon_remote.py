"""The daemon round trip, in-process: serve the simulated cluster over
HTTP, read it back through every client surface, then stack a second
daemon on top of the first (cluster-of-clusters).

    PYTHONPATH=src python examples/daemon_remote.py
"""
from repro.core import cli
from repro.daemon import (LLloadDaemon, RemoteClient, RemoteSource,
                          serve_background)
from repro.monitor import build_source


def main():
    # -- tier 0: a daemon collecting from the simulated LLSC cluster
    daemon = LLloadDaemon(build_source("sim"), ttl_s=5.0)
    daemon.start_sampler(0.2)                  # feed the history store
    server, _ = serve_background(daemon)
    host, port = server.server_address[:2]
    url = f"http://{host}:{port}"
    print(f"daemon up at {url}\n")

    client = RemoteClient(url)
    print("healthz:", client.healthz())
    snap = client.snapshot()
    print(f"snapshot: {len(snap.nodes)} nodes on {snap.cluster!r} "
          f"at t={snap.timestamp:.0f}\n")

    print("the same CLI, over the network (byte-identical to local):")
    cli.main(["--source", "remote", "--url", url, "-t", "3"])

    print("\ntrend (downsampled from the history store):")
    trend = client.trend()
    for p in trend["points"][-3:]:
        nl = p["norm_load"]
        print(f"  t={p['t']:.0f} count={p['count']} "
              f"norm_load min/mean/max = "
              f"{nl['min']:.3f}/{nl['mean']:.3f}/{nl['max']:.3f}")

    print("\nweekly report from store tiers (top entries):")
    weekly = client.weekly()
    for cat in ("low_gpu", "high_cpu"):
        rows = weekly[cat][:2]
        print(f"  {cat}: " + (", ".join(
            f"{r['username']} ({r['node_hours']:.2f} node-h)"
            for r in rows) or "none"))

    print("\nPrometheus exposition (first lines):")
    for line in client.metrics_text().splitlines()[:4]:
        print(" ", line)

    # -- tier 1: a daemon whose source is the first daemon
    upstream = RemoteSource(url, name="tier0")
    top = LLloadDaemon(upstream, ttl_s=5.0)
    top_server, _ = serve_background(top)
    thost, tport = top_server.server_address[:2]
    snap2 = RemoteClient(f"http://{thost}:{tport}").snapshot()
    print(f"\ncluster-of-clusters: tier-1 daemon serves the same "
          f"{len(snap2.nodes)}-node snapshot: "
          f"{snap2 == snap}")

    for srv, d in ((top_server, top), (server, daemon)):
        srv.shutdown()
        srv.server_close()
        d.close()
    print("both daemons stopped cleanly")


if __name__ == "__main__":
    main()

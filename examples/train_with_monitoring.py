"""End-to-end driver: train the ~110M `llsc-100m` model for a few hundred
steps WITH LLload self-reporting, checkpoint/restart and straggler hooks.

    PYTHONPATH=src python examples/train_with_monitoring.py \
        [--steps 240] [--quick] [--crash-at N]

``--quick`` uses the reduced config (CI-speed); the default trains the full
110M model on CPU (batch 4 x seq 64; a few seconds per step).  While
training, the job is visible to LLload exactly like a user job at LLSC:
its duty cycle, memory and step times flow through the collector registry.
"""
import argparse

from repro.configs import get_config, reduced_config
from repro.core.collector import JaxJobRegistry, LocalHostCollector
from repro.launch.fault import CrashInjector
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=240)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--crash-at", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/llsc100m-ckpt")
    args = ap.parse_args()

    cfg = get_config("llsc-100m")
    if args.quick:
        cfg = reduced_config(cfg)
    tcfg = TrainerConfig(steps=args.steps, batch_size=args.batch,
                         seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                         ckpt_every=40, log_every=10,
                         job_name=f"train:{cfg.name}")
    crash = CrashInjector(args.crash_at) if args.crash_at else None
    trainer = Trainer(cfg, tcfg, crash=crash)

    try:
        out = trainer.run(resume=True)
    except RuntimeError as e:
        print(f"!! {e} — restart this script to resume from the last "
              f"checkpoint in {args.ckpt_dir}")
        raise SystemExit(1)

    print(f"\nfinal loss: {out['final_loss']:.4f} "
          f"(resumed from step {out['start_step']})")

    # What LLload sees about this job (the paper's per-user view):
    agg = JaxJobRegistry.global_registry().aggregate()
    print("\nLLload view of this job:")
    print(f"  devices:    {agg.n_devices}")
    print(f"  duty cycle: {agg.duty_cycle:.3f}  (achieved/peak FLOP/s)")
    print(f"  step time:  {agg.step_time_s * 1e3:.0f} ms")
    snap = LocalHostCollector(username="demo").snapshot()
    node = list(snap.nodes.values())[0]
    print(f"  host load:  {node.load:.2f} on {node.cores_total} cores "
          f"(norm {node.norm_load:.2f})")
    if agg.duty_cycle < 0.45:
        print("  -> LLload weekly analysis would flag this job LOW-GPULOAD;"
              " the advisor would suggest overloading (see "
              "examples/overloading_throughput.py)")


if __name__ == "__main__":
    main()
